//! Deterministic fault-injection engine for the decode-hardening suite.
//!
//! The hardened decode path promises: **any** byte stream fed to the
//! container walkers produces either a correct decode or a typed
//! [`crate::util::Error`] — never a panic escape, never unbounded work or
//! allocation.  This module manufactures the adversarial inputs that
//! promise is tested against: seeded, replayable mutations of valid
//! containers (bit flips, truncations, byte splices, length-field
//! inflation, skip-table corruption).
//!
//! Everything is driven by [`crate::util::Pcg64`], so a failing case is
//! reproducible from its seed alone — CI runs a fixed iteration count
//! (see the `fault-smoke` step) and any escape it finds can be replayed
//! locally with the printed seed.
//!
//! Mutations that leave the trailing CRC stale are caught cheaply by the
//! CRC gate at container open; [`restamp`] recomputes the trailing CRC so
//! a mutation *penetrates* that gate and exercises the header/payload
//! validation behind it.  The engine emits both flavours.

use crate::model::{Kind, Layer, Network};
use crate::util::{crc32, Pcg64};

/// The mutation classes the engine draws from.  Kept public so property
/// tests can name the class that produced a failing case.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MutationKind {
    /// Flip one bit anywhere in the stream.
    BitFlip,
    /// Cut the stream to a strictly shorter prefix.
    Truncate,
    /// Overwrite a short window with random bytes.
    Splice,
    /// Overwrite a 4-byte window with a huge little-endian u32 — the
    /// length-field-inflation attack (name_len / rows / cols / bias_len /
    /// payload_len / slice counts all ride u16/u32 fields).
    InflateLength,
    /// Corrupt a byte in the header region (first 64 bytes after the
    /// magic) — covers the v4 skip-flag table, layer counts and the
    /// coding-config fields.
    CorruptHeader,
}

/// All kinds, in draw order.
pub const ALL_KINDS: [MutationKind; 5] = [
    MutationKind::BitFlip,
    MutationKind::Truncate,
    MutationKind::Splice,
    MutationKind::InflateLength,
    MutationKind::CorruptHeader,
];

/// One applied mutation, for replayable failure reports.
#[derive(Clone, Debug)]
pub struct MutationReport {
    pub kind: MutationKind,
    /// Byte offset the mutation anchored at (0 for truncation-to-empty).
    pub offset: usize,
    /// Whether the trailing CRC was restamped after mutating, letting the
    /// mutation penetrate the CRC gate.
    pub restamped: bool,
}

/// Recompute the trailing CRC-32 of a DCB container in place (the wire
/// format stores `crc32(body)` over everything after the 4-byte magic as
/// the final little-endian u32).  No-op on streams too short to carry
/// both magic and CRC — those exercise the truncation paths as-is.
pub fn restamp(raw: &mut [u8]) {
    let n = raw.len();
    if n < 8 {
        return;
    }
    let crc = crc32(&raw[4..n - 4]);
    raw[n - 4..].copy_from_slice(&crc.to_le_bytes());
}

/// Flip bit `bit` (0..8) of byte `byte` — the primitive the exhaustive
/// single-byte sweep in `tests/fault_injection.rs` drives directly.
pub fn flip_bit(raw: &mut [u8], byte: usize, bit: u32) {
    raw[byte] ^= 1u8 << (bit % 8);
}

/// The IEEE-754 specials the adversarial network generator salts planes
/// with: the values the encode-hardening contract must survive (typed
/// error under `Reject`, bit-exact round-trip after `Sanitize`/`Clamp`),
/// plus the finite extremes that stress Δ-division overflow paths.
pub const SPECIAL_F32: [f32; 8] = [
    f32::NAN,
    f32::INFINITY,
    f32::NEG_INFINITY,
    1.0e-41,  // subnormal
    -1.0e-41, // negative subnormal
    -0.0,
    f32::MAX,
    f32::MIN,
];

/// Seeded adversarial [`Network`] generator for the encode-side fuzz
/// campaign (`tests/encode_fuzz.rs`): pathological shapes (empty planes,
/// 1×1, long ribbons) with weight/importance/bias planes salted with
/// [`SPECIAL_F32`] values.  Roughly a third of the draws come out clean so
/// the campaign also exercises the scan-only fast path.  Deterministic per
/// seed.
pub struct NetGen {
    rng: Pcg64,
}

impl NetGen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg64::new(seed),
        }
    }

    fn weight_plane(&mut self, n: usize, dirty: bool) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if dirty && self.rng.next_f64() < 0.08 {
                    SPECIAL_F32[self.rng.below(SPECIAL_F32.len() as u64) as usize]
                } else {
                    (self.rng.next_f64() as f32 - 0.5) * 0.4
                }
            })
            .collect()
    }

    fn importance_plane(&mut self, n: usize, dirty: bool) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if dirty && self.rng.next_f64() < 0.08 {
                    // invalid importance: non-finite OR negative
                    match self.rng.below(4) {
                        0 => f32::NAN,
                        1 => f32::INFINITY,
                        2 => -1.0,
                        _ => f32::NEG_INFINITY,
                    }
                } else {
                    self.rng.next_f64() as f32 + 0.01
                }
            })
            .collect()
    }

    /// One adversarial (but structurally *valid*) network: shapes pass
    /// [`Network::validate`], values may not pass the non-finite policy.
    pub fn adversarial(&mut self) -> Network {
        let n_layers = 1 + self.rng.below(4) as usize;
        let dirty_net = self.rng.next_f64() < 0.67;
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let (rows, cols) = match self.rng.below(6) {
                0 => (0, self.rng.below(8) as usize), // empty plane
                1 => (1, 1),
                2 => (1, 1 + self.rng.below(96) as usize), // ribbons
                3 => (1 + self.rng.below(96) as usize, 1),
                _ => (
                    1 + self.rng.below(24) as usize,
                    1 + self.rng.below(24) as usize,
                ),
            };
            let n = rows * cols;
            let dirty = dirty_net && self.rng.next_f64() < 0.8;
            let fisher = (self.rng.below(2) == 1).then(|| self.importance_plane(n, dirty));
            let hessian = (self.rng.below(4) == 0).then(|| self.importance_plane(n, dirty));
            let bias =
                (self.rng.below(2) == 1).then(|| self.weight_plane(rows.clamp(1, 8), dirty));
            layers.push(Layer {
                name: format!("l{i}"),
                kind: Kind::Dense,
                shape: vec![cols, rows],
                rows,
                cols,
                weights: self.weight_plane(n, dirty),
                fisher,
                hessian,
                bias,
            });
        }
        Network {
            name: "adversarial".into(),
            layers,
        }
    }
}

/// Seeded mutation engine: each [`Mutator::mutate`] call draws one
/// mutation class, applies it to a copy of `raw`, and (half the time)
/// restamps the CRC so the mutation reaches the validation behind the
/// CRC gate.  Identical seeds produce identical mutation sequences.
pub struct Mutator {
    rng: Pcg64,
}

impl Mutator {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg64::new(seed),
        }
    }

    /// Apply one random mutation to a copy of `raw`.
    pub fn mutate(&mut self, raw: &[u8]) -> (Vec<u8>, MutationReport) {
        let mut out = raw.to_vec();
        let kind = ALL_KINDS[self.rng.below(ALL_KINDS.len() as u64) as usize];
        let offset = self.apply(kind, &mut out);
        let restamped = self.rng.below(2) == 1 && kind != MutationKind::Truncate;
        if restamped {
            restamp(&mut out);
        }
        (
            out,
            MutationReport {
                kind,
                offset,
                restamped,
            },
        )
    }

    fn apply(&mut self, kind: MutationKind, out: &mut Vec<u8>) -> usize {
        if out.is_empty() {
            return 0;
        }
        let n = out.len();
        match kind {
            MutationKind::BitFlip => {
                let at = self.rng.below(n as u64) as usize;
                flip_bit(out, at, self.rng.below(8) as u32);
                at
            }
            MutationKind::Truncate => {
                let keep = self.rng.below(n as u64) as usize;
                out.truncate(keep);
                keep
            }
            MutationKind::Splice => {
                let at = self.rng.below(n as u64) as usize;
                let len = (1 + self.rng.below(16) as usize).min(n - at);
                for b in &mut out[at..at + len] {
                    *b = self.rng.next_u32() as u8;
                }
                at
            }
            MutationKind::InflateLength => {
                let at = self.rng.below(n.saturating_sub(3).max(1) as u64) as usize;
                let huge: u32 = match self.rng.below(4) {
                    0 => u32::MAX,
                    1 => i32::MAX as u32,
                    2 => 1 << 30,
                    _ => 0xFFFF,
                };
                let end = (at + 4).min(n);
                out[at..end].copy_from_slice(&huge.to_le_bytes()[..end - at]);
                at
            }
            MutationKind::CorruptHeader => {
                let hdr = n.min(64);
                let at = self.rng.below(hdr as u64) as usize;
                out[at] = out[at].wrapping_add(1 + self.rng.next_u32() as u8);
                at
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutator_is_deterministic_per_seed() {
        let base: Vec<u8> = (0u8..=255).cycle().take(600).collect();
        let run = |seed| {
            let mut m = Mutator::new(seed);
            (0..50).map(|_| m.mutate(&base).0).collect::<Vec<_>>()
        };
        assert_eq!(run(42), run(42), "same seed, same mutation stream");
        assert_ne!(run(42), run(43), "different seeds diverge");
    }

    #[test]
    fn mutations_actually_change_the_stream() {
        let base: Vec<u8> = (0u8..=255).cycle().take(600).collect();
        let mut m = Mutator::new(7);
        let changed = (0..100).filter(|_| m.mutate(&base).0 != base).count();
        // Truncate-to-full-length is the only no-op draw; nearly all
        // mutations must differ from the pristine stream.
        assert!(changed >= 95, "only {changed}/100 mutations changed bytes");
    }

    #[test]
    fn restamp_rewrites_trailing_crc() {
        let mut raw = vec![b'D', b'C', b'B', b'1', 9, 8, 7, 6, 0, 0, 0, 0];
        restamp(&mut raw);
        let n = raw.len();
        let want = crc32(&raw[4..n - 4]);
        assert_eq!(raw[n - 4..], want.to_le_bytes());
        // idempotent: the body did not change, so neither does the stamp
        let copy = raw.clone();
        restamp(&mut raw);
        assert_eq!(raw, copy);
        // too-short streams are left alone
        let mut tiny = vec![1, 2, 3];
        restamp(&mut tiny);
        assert_eq!(tiny, vec![1, 2, 3]);
    }

    #[test]
    fn netgen_is_deterministic_and_valid() {
        let nets = |seed| {
            let mut g = NetGen::new(seed);
            (0..30).map(|_| g.adversarial()).collect::<Vec<_>>()
        };
        let a = nets(9);
        let b = nets(9);
        for (x, y) in a.iter().zip(&b) {
            // f32 NaN != NaN, so compare bit patterns
            assert_eq!(x.layers.len(), y.layers.len());
            for (lx, ly) in x.layers.iter().zip(&y.layers) {
                assert!(lx
                    .weights
                    .iter()
                    .zip(&ly.weights)
                    .all(|(p, q)| p.to_bits() == q.to_bits()));
            }
            x.validate().expect("adversarial nets are structurally valid");
        }
        // the salt actually lands: across 30 draws some plane is dirty and
        // some network is fully clean
        let dirty = a
            .iter()
            .filter(|n| n.layers.iter().any(|l| l.weight_census().non_finite() > 0))
            .count();
        assert!(dirty > 0, "no dirty draw in 30");
        assert!(dirty < 30, "no clean draw in 30");
    }

    #[test]
    fn flip_bit_is_involutive() {
        let mut raw = vec![0b1010_1010u8; 4];
        flip_bit(&mut raw, 2, 3);
        assert_eq!(raw[2], 0b1010_0010);
        flip_bit(&mut raw, 2, 3);
        assert_eq!(raw[2], 0b1010_1010);
    }
}
