//! Mini property-testing framework (offline stand-in for proptest —
//! DESIGN.md §6): seeded random generation, configurable case counts, and
//! greedy input shrinking on failure.
//!
//! ```ignore
//! testutil::check(200, gen_symbols, |case| prop_roundtrip(case));
//! ```
//! On failure the framework re-runs the predicate on progressively smaller
//! inputs (halving slices) and panics with the smallest failing case's seed
//! + length so the case can be replayed deterministically.

use crate::util::Pcg64;

pub mod fuzz;

/// Configuration for one property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 100,
            seed: 0xDEC0DE,
        }
    }
}

/// Run `prop` on `cases` random inputs from `gen`.  Panics on the first
/// failure with a replayable seed, after shrinking.
pub fn check<T, G, P>(cfg: Config, mut gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: Fn(&T) -> bool,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg64::new(case_seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed (case {case}, seed {case_seed:#x}): {:?}",
                Summary(&input)
            );
        }
    }
}

/// Like [`check`] but for slice-valued cases, with greedy shrinking: on
/// failure, tries prefixes/suffixes/halves to find a minimal failing slice.
pub fn check_slice<T, G, P>(cfg: Config, mut gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> Vec<T>,
    P: Fn(&[T]) -> bool,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg64::new(case_seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink(&input, &prop);
            panic!(
                "property failed (case {case}, seed {case_seed:#x}), shrunk {} -> {} elems: {:?}",
                input.len(),
                minimal.len(),
                &minimal[..minimal.len().min(32)]
            );
        }
    }
}

/// Greedy bisection shrink: repeatedly drop halves/quarters while the
/// property still fails.
pub fn shrink<T: Clone, P: Fn(&[T]) -> bool>(input: &[T], prop: &P) -> Vec<T> {
    let mut cur = input.to_vec();
    loop {
        let n = cur.len();
        if n <= 1 {
            return cur;
        }
        let mut improved = false;
        // try dropping chunks of size n/2, n/4, ... 1
        let mut chunk = n / 2;
        while chunk >= 1 {
            let mut start = 0;
            while start < cur.len() {
                let mut candidate = Vec::with_capacity(cur.len().saturating_sub(chunk));
                candidate.extend_from_slice(&cur[..start]);
                candidate.extend_from_slice(&cur[(start + chunk).min(cur.len())..]);
                if candidate.len() < cur.len() && !prop(&candidate) {
                    cur = candidate;
                    improved = true;
                    break;
                }
                start += chunk;
            }
            if improved {
                break;
            }
            chunk /= 2;
        }
        if !improved {
            return cur;
        }
    }
}

struct Summary<'a, T>(&'a T);

impl<T: std::fmt::Debug> std::fmt::Debug for Summary<'_, T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = format!("{:?}", self.0);
        if s.len() > 400 {
            write!(f, "{}… ({} chars)", &s[..400], s.len())
        } else {
            write!(f, "{s}")
        }
    }
}

/// Common generators.
pub mod gen {
    use crate::util::Pcg64;

    /// Sparse integer symbol plane, DeepCABAC's working distribution.
    pub fn sparse_symbols(rng: &mut Pcg64) -> Vec<i32> {
        let n = rng.below(3000) as usize;
        let zero_p = rng.uniform(0.2, 0.95);
        let mag = 1 + rng.below(100) as i32;
        (0..n)
            .map(|_| {
                if rng.next_f64() < zero_p {
                    0
                } else {
                    let m = 1 + (rng.next_f64() * rng.next_f64() * mag as f64) as i32;
                    if rng.next_f64() < 0.5 {
                        -m
                    } else {
                        m
                    }
                }
            })
            .collect()
    }

    /// Arbitrary (including extreme) integer streams.
    pub fn wild_symbols(rng: &mut Pcg64) -> Vec<i32> {
        let n = rng.below(800) as usize;
        (0..n)
            .map(|_| match rng.below(5) {
                0 => 0,
                1 => rng.below(10) as i32 - 5,
                2 => rng.below(1000) as i32 - 500,
                3 => rng.below(1_000_000) as i32 - 500_000,
                _ => (rng.next_u32() as i32) / 4, // avoid i32::MIN overflow on abs
            })
            .collect()
    }

    /// Realistic weight vectors (sparse Laplacian).
    pub fn weights(rng: &mut Pcg64) -> Vec<f32> {
        let n = 1 + rng.below(4000) as usize;
        let scale = rng.uniform(0.005, 0.3) as f32;
        let zf = rng.uniform(0.0, 0.9);
        rng.sparse_laplace_vec(n, scale, zf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check(
            Config {
                cases: 50,
                seed: 1,
            },
            |rng| rng.below(100),
            |&x| x < 100,
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            Config {
                cases: 50,
                seed: 2,
            },
            |rng| rng.below(100),
            |&x| x < 50,
        );
    }

    #[test]
    fn shrink_finds_small_case() {
        // property: "no element equals 7" — shrink must reduce to [7].
        let input: Vec<i32> = (0..100).collect();
        let minimal = shrink(&input, &|s: &[i32]| !s.contains(&7));
        assert_eq!(minimal, vec![7]);
    }

    #[test]
    fn shrink_keeps_failing_invariant() {
        // property fails iff sum > 50
        let input = vec![10i32; 20];
        let minimal = shrink(&input, &|s: &[i32]| s.iter().sum::<i32>() <= 50);
        assert!(minimal.iter().sum::<i32>() > 50);
        assert_eq!(minimal.len(), 6); // smallest multiple of 10 over 50
    }

    #[test]
    fn generators_honour_seed() {
        let mut a = Pcg64::new(99);
        let mut b = Pcg64::new(99);
        assert_eq!(gen::sparse_symbols(&mut a), gen::sparse_symbols(&mut b));
    }
}
