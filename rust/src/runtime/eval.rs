//! Accuracy evaluation of (possibly quantized) networks through the AOT
//! eval graphs — the paper's accuracy oracle (§III-A step 5).

use super::pjrt::{Engine, EVAL_BATCH};
use crate::data::Dataset;
use crate::model::Network;
use crate::util::{Error, Result};

/// Engine + dataset bundled into an accuracy oracle.
pub struct Evaluator {
    pub engine: Engine,
    pub dataset: Dataset,
}

impl Evaluator {
    pub fn new(engine: Engine, dataset: Dataset) -> Self {
        Self { engine, dataset }
    }

    /// Top-1 accuracy of `net` (its `name` selects the eval graph family —
    /// a `<arch>_sparse` network evaluates through `eval_<arch>`).
    pub fn accuracy(&self, net: &Network) -> Result<f64> {
        let arch = net.name.trim_end_matches("_sparse");
        let mats: Vec<(&[f32], usize, usize)> = net
            .layers
            .iter()
            .map(|l| (l.weights.as_slice(), l.rows, l.cols))
            .collect();
        let biases: Vec<&[f32]> = net
            .layers
            .iter()
            .map(|l| {
                l.bias
                    .as_deref()
                    .ok_or_else(|| Error::Config(format!("layer {} missing bias", l.name)))
            })
            .collect::<Result<_>>()?;
        let d = &self.dataset;
        if d.n % EVAL_BATCH != 0 {
            return Err(Error::Config(format!(
                "dataset size {} not a multiple of eval batch {EVAL_BATCH}",
                d.n
            )));
        }
        let mut correct = 0usize;
        for b in 0..d.n / EVAL_BATCH {
            let x = d.batch_images(b * EVAL_BATCH, EVAL_BATCH);
            let logits =
                self.engine
                    .eval_logits(arch, &mats, &biases, x, (d.h, d.w, d.c))?;
            let labels = d.batch_labels(b * EVAL_BATCH, EVAL_BATCH);
            correct += count_correct(&logits, labels, d.classes);
        }
        Ok(correct as f64 / d.n as f64)
    }
}

/// Top-1 matches in a flat logits buffer.
pub fn count_correct(logits: &[f32], labels: &[u8], classes: usize) -> usize {
    logits
        .chunks_exact(classes)
        .zip(labels)
        .filter(|(row, &y)| {
            let mut best = 0usize;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            best == y as usize
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_correct_basic() {
        // 3 samples, 4 classes
        let logits = vec![
            0.1, 0.9, 0.0, 0.0, // -> 1
            5.0, 1.0, 2.0, 3.0, // -> 0
            0.0, 0.0, 0.1, 0.2, // -> 3
        ];
        assert_eq!(count_correct(&logits, &[1, 0, 3], 4), 3);
        assert_eq!(count_correct(&logits, &[1, 1, 1], 4), 1);
        assert_eq!(count_correct(&logits, &[0, 1, 2], 4), 0);
    }

    #[test]
    fn count_correct_tie_prefers_first() {
        let logits = vec![0.5, 0.5];
        assert_eq!(count_correct(&logits, &[0], 2), 1);
        assert_eq!(count_correct(&logits, &[1], 2), 0);
    }
}
