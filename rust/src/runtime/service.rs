//! Evaluation service: a dedicated runtime thread owning the (non-`Send`)
//! PJRT engine, fronted by a cloneable channel handle.
//!
//! This is the leader/worker split of the coordinator: grid-search workers
//! (pure Rust, CPU-parallel) quantize + encode candidates, then submit
//! reconstructed networks here for accuracy scoring.  The request channel is
//! bounded — quantizers naturally outpace the eval graph, and the bound
//! provides backpressure instead of unbounded queue growth.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use super::eval::Evaluator;
use super::pjrt::Engine;
use crate::data::Dataset;
use crate::model::Network;
use crate::util::{Error, Result};

enum Request {
    Accuracy {
        net: Box<Network>,
        reply: mpsc::Sender<Result<f64>>,
    },
    RdAssign {
        w: Vec<f32>,
        fim: Vec<f32>,
        delta: f32,
        lambda: f32,
        cost: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<i32>>>,
    },
    Shutdown,
}

/// Cloneable handle to an accuracy oracle: either the PJRT runtime thread
/// ([`EvalService::spawn`]) or an in-process scoring function
/// ([`EvalService::from_fn`] — deterministic proxy oracles for tests and
/// benches, which must exercise the full grid-search machinery on machines
/// without the AOT artifacts or the real xla bindings).
#[derive(Clone)]
pub struct EvalService {
    inner: Inner,
}

#[derive(Clone)]
enum Inner {
    /// Channel into the dedicated PJRT runtime thread.
    Pjrt(mpsc::SyncSender<Request>),
    /// In-process accuracy function (no device kernel available).
    Local(std::sync::Arc<dyn Fn(&Network) -> Result<f64> + Send + Sync>),
}

/// Owns the runtime thread; dropping it shuts the thread down.
pub struct EvalServiceHost {
    pub handle: EvalService,
    join: Option<JoinHandle<()>>,
    tx: mpsc::SyncSender<Request>,
}

impl EvalService {
    /// Spawn the runtime thread.  `queue` bounds in-flight requests
    /// (backpressure for the grid search).
    pub fn spawn(artifacts: PathBuf, dataset_path: PathBuf, queue: usize) -> Result<EvalServiceHost> {
        let (tx, rx) = mpsc::sync_channel::<Request>(queue.max(1));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-eval".into())
            .spawn(move || {
                let built: Result<Evaluator> = (|| {
                    let engine = Engine::new(&artifacts)?;
                    let dataset = Dataset::load(&dataset_path)?;
                    Ok(Evaluator::new(engine, dataset))
                })();
                let evaluator = match built {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Accuracy { net, reply } => {
                            let _ = reply.send(evaluator.accuracy(&net));
                        }
                        Request::RdAssign {
                            w,
                            fim,
                            delta,
                            lambda,
                            cost,
                            reply,
                        } => {
                            let _ = reply.send(
                                evaluator.engine.rd_assign(&w, &fim, delta, lambda, &cost),
                            );
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(|e| Error::Config(format!("spawn eval thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Config("eval thread died during init".into()))??;
        Ok(EvalServiceHost {
            handle: EvalService {
                inner: Inner::Pjrt(tx.clone()),
            },
            join: Some(join),
            tx,
        })
    }

    /// An in-process accuracy oracle from a plain function — no PJRT, no
    /// artifacts, no runtime thread.  The function must be deterministic if
    /// the caller relies on reproducible search outcomes (the seeded
    /// search-strategy tests and the search benches do).  Device-kernel
    /// requests ([`Self::rd_assign`]) are unavailable on this backend.
    pub fn from_fn<F>(f: F) -> EvalService
    where
        F: Fn(&Network) -> Result<f64> + Send + Sync + 'static,
    {
        EvalService {
            inner: Inner::Local(std::sync::Arc::new(f)),
        }
    }

    /// Blocking accuracy request.
    pub fn accuracy(&self, net: &Network) -> Result<f64> {
        match &self.inner {
            Inner::Local(f) => f(net),
            Inner::Pjrt(tx) => {
                let (reply, rx) = mpsc::channel();
                tx.send(Request::Accuracy {
                    net: Box::new(net.clone()),
                    reply,
                })
                .map_err(|_| Error::Config("eval service down".into()))?;
                rx.recv()
                    .map_err(|_| Error::Config("eval service dropped reply".into()))?
            }
        }
    }

    /// Blocking device-kernel RDOQ request (Pallas rd_assign via PJRT).
    pub fn rd_assign(
        &self,
        w: &[f32],
        fim: &[f32],
        delta: f32,
        lambda: f32,
        cost: &[f32],
    ) -> Result<Vec<i32>> {
        match &self.inner {
            Inner::Local(_) => Err(Error::Config(
                "rd_assign unavailable: local eval oracle has no device kernel".into(),
            )),
            Inner::Pjrt(tx) => {
                let (reply, rx) = mpsc::channel();
                tx.send(Request::RdAssign {
                    w: w.to_vec(),
                    fim: fim.to_vec(),
                    delta,
                    lambda,
                    cost: cost.to_vec(),
                    reply,
                })
                .map_err(|_| Error::Config("eval service down".into()))?;
                rx.recv()
                    .map_err(|_| Error::Config("eval service dropped reply".into()))?
            }
        }
    }
}

impl Drop for EvalServiceHost {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_oracle_scores_and_rejects_kernel_requests() {
        let svc = EvalService::from_fn(|net: &Network| Ok(net.layers.len() as f64 / 10.0));
        let net = Network {
            name: "t".into(),
            layers: Vec::new(),
        };
        assert_eq!(svc.accuracy(&net).unwrap(), 0.0);
        // cloneable + usable across threads like the PJRT handle
        let c = svc.clone();
        std::thread::scope(|s| {
            s.spawn(move || assert_eq!(c.accuracy(&net).unwrap(), 0.0));
        });
        assert!(svc.rd_assign(&[0.0], &[1.0], 0.1, 0.0, &[1.0]).is_err());
    }
}
