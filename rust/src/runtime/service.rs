//! Evaluation service: a dedicated runtime thread owning the (non-`Send`)
//! PJRT engine, fronted by a cloneable channel handle.
//!
//! This is the leader/worker split of the coordinator: grid-search workers
//! (pure Rust, CPU-parallel) quantize + encode candidates, then submit
//! reconstructed networks here for accuracy scoring.  The request channel is
//! bounded — quantizers naturally outpace the eval graph, and the bound
//! provides backpressure instead of unbounded queue growth.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use super::eval::Evaluator;
use super::pjrt::Engine;
use crate::data::Dataset;
use crate::model::{decode_network_into, DecodeArena, Network};
use crate::util::{Error, Result};

enum Request {
    Accuracy {
        net: Box<Network>,
        reply: mpsc::Sender<Result<f64>>,
    },
    /// Score a serialized `.dcb` container: the runtime thread decodes it
    /// through its persistent [`DecodeArena`] (fused bytes→floats, zero
    /// steady-state allocation for same-shaped models) and evaluates the
    /// arena-resident network — the inference-from-compressed request
    /// shape.
    AccuracyCompressed {
        bytes: Vec<u8>,
        reply: mpsc::Sender<Result<f64>>,
    },
    RdAssign {
        w: Vec<f32>,
        fim: Vec<f32>,
        delta: f32,
        lambda: f32,
        cost: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<i32>>>,
    },
    Shutdown,
}

/// Cloneable handle to an accuracy oracle: either the PJRT runtime thread
/// ([`EvalService::spawn`]) or an in-process scoring function
/// ([`EvalService::from_fn`] — deterministic proxy oracles for tests and
/// benches, which must exercise the full grid-search machinery on machines
/// without the AOT artifacts or the real xla bindings).
#[derive(Clone)]
pub struct EvalService {
    inner: Inner,
}

#[derive(Clone)]
enum Inner {
    /// Channel into the dedicated PJRT runtime thread.
    Pjrt(mpsc::SyncSender<Request>),
    /// In-process accuracy function (no device kernel available).
    Local(std::sync::Arc<dyn Fn(&Network) -> Result<f64> + Send + Sync>),
}

/// Owns the runtime thread; dropping it shuts the thread down.
pub struct EvalServiceHost {
    pub handle: EvalService,
    join: Option<JoinHandle<()>>,
    tx: mpsc::SyncSender<Request>,
}

impl EvalService {
    /// Spawn the runtime thread.  `queue` bounds in-flight requests
    /// (backpressure for the grid search).
    pub fn spawn(artifacts: PathBuf, dataset_path: PathBuf, queue: usize) -> Result<EvalServiceHost> {
        let (tx, rx) = mpsc::sync_channel::<Request>(queue.max(1));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-eval".into())
            .spawn(move || {
                let built: Result<Evaluator> = (|| {
                    let engine = Engine::new(&artifacts)?;
                    let dataset = Dataset::load(&dataset_path)?;
                    Ok(Evaluator::new(engine, dataset))
                })();
                let evaluator = match built {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // Persistent fused-decode arena: repeated scoring of
                // same-shaped containers decodes allocation-free, and the
                // model becomes eval-thread-resident in one pass.
                let mut arena = DecodeArena::new();
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Accuracy { net, reply } => {
                            let _ = reply.send(evaluator.accuracy(&net));
                        }
                        Request::AccuracyCompressed { bytes, reply } => {
                            // Serial decode by design: grid-search workers
                            // block on this thread's replies while they may
                            // hold the shared worker pool, so borrowing the
                            // pool here could deadlock against them.  The
                            // fused zero-allocation path is the win on this
                            // thread; fan-out belongs to the caller's side.
                            let _ = reply.send(
                                decode_network_into(&bytes, 1, &mut arena)
                                    .and_then(|net| evaluator.accuracy(net)),
                            );
                        }
                        Request::RdAssign {
                            w,
                            fim,
                            delta,
                            lambda,
                            cost,
                            reply,
                        } => {
                            let _ = reply.send(
                                evaluator.engine.rd_assign(&w, &fim, delta, lambda, &cost),
                            );
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(|e| Error::Config(format!("spawn eval thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Config("eval thread died during init".into()))??;
        Ok(EvalServiceHost {
            handle: EvalService {
                inner: Inner::Pjrt(tx.clone()),
            },
            join: Some(join),
            tx,
        })
    }

    /// An in-process accuracy oracle from a plain function — no PJRT, no
    /// artifacts, no runtime thread.  The function must be deterministic if
    /// the caller relies on reproducible search outcomes (the seeded
    /// search-strategy tests and the search benches do).  Device-kernel
    /// requests ([`Self::rd_assign`]) are unavailable on this backend.
    pub fn from_fn<F>(f: F) -> EvalService
    where
        F: Fn(&Network) -> Result<f64> + Send + Sync + 'static,
    {
        EvalService {
            inner: Inner::Local(std::sync::Arc::new(f)),
        }
    }

    /// Blocking accuracy request.
    pub fn accuracy(&self, net: &Network) -> Result<f64> {
        match &self.inner {
            Inner::Local(f) => f(net),
            Inner::Pjrt(tx) => {
                let (reply, rx) = mpsc::channel();
                tx.send(Request::Accuracy {
                    net: Box::new(net.clone()),
                    reply,
                })
                .map_err(|_| Error::Config("eval service down".into()))?;
                rx.recv()
                    .map_err(|_| Error::Config("eval service dropped reply".into()))?
            }
        }
    }

    /// Blocking accuracy request on a **serialized `.dcb` container** —
    /// the fused decode→inference path.  On the PJRT backend the runtime
    /// thread decodes through its persistent [`DecodeArena`], so repeated
    /// scoring of same-shaped containers allocates nothing in steady
    /// state; the in-process backend decodes with a call-local arena
    /// (still single-pass fused, no intermediate `i32` planes).
    ///
    /// A serving loop that owns the container should **move** its
    /// `Vec<u8>` in (no copy on the way to the runtime thread); passing
    /// `&[u8]` works too and pays one copy on the PJRT backend only (the
    /// in-process backend decodes straight from the borrow).
    pub fn accuracy_compressed(&self, raw: impl AsRef<[u8]> + Into<Vec<u8>>) -> Result<f64> {
        match &self.inner {
            Inner::Local(f) => {
                let mut arena = DecodeArena::new();
                let threads = crate::util::parallel::default_threads();
                let net = decode_network_into(raw.as_ref(), threads, &mut arena)?;
                f(net)
            }
            Inner::Pjrt(tx) => {
                let (reply, rx) = mpsc::channel();
                tx.send(Request::AccuracyCompressed {
                    bytes: raw.into(),
                    reply,
                })
                .map_err(|_| Error::Config("eval service down".into()))?;
                rx.recv()
                    .map_err(|_| Error::Config("eval service dropped reply".into()))?
            }
        }
    }

    /// Blocking device-kernel RDOQ request (Pallas rd_assign via PJRT).
    pub fn rd_assign(
        &self,
        w: &[f32],
        fim: &[f32],
        delta: f32,
        lambda: f32,
        cost: &[f32],
    ) -> Result<Vec<i32>> {
        match &self.inner {
            Inner::Local(_) => Err(Error::Config(
                "rd_assign unavailable: local eval oracle has no device kernel".into(),
            )),
            Inner::Pjrt(tx) => {
                let (reply, rx) = mpsc::channel();
                tx.send(Request::RdAssign {
                    w: w.to_vec(),
                    fim: fim.to_vec(),
                    delta,
                    lambda,
                    cost: cost.to_vec(),
                    reply,
                })
                .map_err(|_| Error::Config("eval service down".into()))?;
                rx.recv()
                    .map_err(|_| Error::Config("eval service dropped reply".into()))?
            }
        }
    }
}

impl Drop for EvalServiceHost {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_oracle_scores_and_rejects_kernel_requests() {
        let svc = EvalService::from_fn(|net: &Network| Ok(net.layers.len() as f64 / 10.0));
        let net = Network {
            name: "t".into(),
            layers: Vec::new(),
        };
        assert_eq!(svc.accuracy(&net).unwrap(), 0.0);
        // cloneable + usable across threads like the PJRT handle
        let c = svc.clone();
        std::thread::scope(|s| {
            s.spawn(move || assert_eq!(c.accuracy(&net).unwrap(), 0.0));
        });
        assert!(svc.rd_assign(&[0.0], &[1.0], 0.1, 0.0, &[1.0]).is_err());
    }

    #[test]
    fn accuracy_compressed_matches_two_pass_reconstruction() {
        use crate::model::{CompressedNetwork, ContainerPolicy, Kind, QuantizedLayer};
        let comp = CompressedNetwork {
            name: "svc".into(),
            cfg: crate::cabac::CodingConfig::default(),
            layers: vec![QuantizedLayer {
                name: "fc".into(),
                kind: Kind::Dense,
                shape: vec![4, 3],
                rows: 3,
                cols: 4,
                ints: vec![0, 1, -2, 0, 5, 0, -1, 3, 0, 0, 2, -4],
                delta: 0.25,
                bias: None,
            }],
        };
        let bytes = comp.to_bytes_with(ContainerPolicy::default());
        // oracle: mean |w| — sensitive to every decoded value
        let svc = EvalService::from_fn(|net: &Network| {
            let (mut s, mut n) = (0f64, 0usize);
            for l in &net.layers {
                n += l.weights.len();
                s += l.weights.iter().map(|w| w.abs() as f64).sum::<f64>();
            }
            Ok(s / n.max(1) as f64)
        });
        let direct = svc.accuracy(&comp.reconstruct_named()).unwrap();
        // borrowed form (pays a copy) and moved form must agree
        let fused = svc.accuracy_compressed(&bytes[..]).unwrap();
        assert_eq!(fused, direct);
        assert_eq!(svc.accuracy_compressed(bytes).unwrap(), direct);
        // corrupt container surfaces as Err, not a panic
        assert!(svc.accuracy_compressed(&b"garbage"[..]).is_err());
    }
}
