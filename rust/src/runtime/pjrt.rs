//! PJRT engine: loads AOT HLO-text artifacts, compiles them once on the CPU
//! client, and executes them from the Rust hot path.
//!
//! Interchange is HLO **text** (`eval_<model>.hlo.txt`, `rd_assign.hlo.txt`,
//! `dequant.hlo.txt`): jax >= 0.5 emits serialized protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so an [`Engine`] is pinned to
//! one thread; multi-threaded callers go through
//! [`super::service::EvalService`].

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::util::{Error, Result};

/// Grid half-width supported by the AOT rd_assign kernel (K = 1025).
pub const KERNEL_K: usize = 1025;
pub const KERNEL_HALF: i32 = (KERNEL_K as i32 - 1) / 2;
/// Chunk length the kernel was lowered for.
pub const KERNEL_N: usize = 16384;
/// Eval graph batch size (must match python/compile/aot.py EVAL_BATCH).
pub const EVAL_BATCH: usize = 256;

/// One-thread PJRT engine with a compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU engine rooted at the artifacts directory.
    pub fn new(artifacts: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            artifacts: artifacts.as_ref().to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (cached) an HLO text artifact by file stem.
    pub fn executable(&self, stem: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(stem) {
            return Ok(e.clone());
        }
        let path = self.artifacts.join(format!("{stem}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Config(format!(
                "artifact {} not found — run `make artifacts`",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Config("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(stem.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute the eval graph of `model`: `mats` are (data, rows, cols) in
    /// scan order, `biases` per layer, `x` one NHWC batch of EVAL_BATCH
    /// images.  Returns the flat logits (EVAL_BATCH × classes).
    pub fn eval_logits(
        &self,
        model: &str,
        mats: &[(&[f32], usize, usize)],
        biases: &[&[f32]],
        x: &[f32],
        img_hw: (usize, usize, usize),
    ) -> Result<Vec<f32>> {
        let exe = self.executable(&format!("eval_{model}"))?;
        let mut args: Vec<xla::Literal> = Vec::with_capacity(mats.len() * 2 + 1);
        for &(data, rows, cols) in mats {
            debug_assert_eq!(data.len(), rows * cols);
            args.push(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?);
        }
        for &b in biases {
            args.push(xla::Literal::vec1(b));
        }
        let (h, w, c) = img_hw;
        debug_assert_eq!(x.len(), EVAL_BATCH * h * w * c);
        args.push(xla::Literal::vec1(x).reshape(&[
            EVAL_BATCH as i64,
            h as i64,
            w as i64,
            c as i64,
        ])?);
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let logits = result.to_tuple1()?;
        Ok(logits.to_vec::<f32>()?)
    }

    /// Execute the AOT Pallas RDOQ kernel on one padded chunk
    /// (length KERNEL_N; cost table length KERNEL_K).
    pub fn rd_assign_chunk(
        &self,
        w: &[f32],
        fim: &[f32],
        delta: f32,
        lambda: f32,
        cost: &[f32],
    ) -> Result<Vec<i32>> {
        if w.len() != KERNEL_N || fim.len() != KERNEL_N || cost.len() != KERNEL_K {
            return Err(Error::Config(format!(
                "rd_assign_chunk expects n={KERNEL_N}, k={KERNEL_K}; got n={} k={}",
                w.len(),
                cost.len()
            )));
        }
        let exe = self.executable("rd_assign")?;
        let args = [
            xla::Literal::vec1(w),
            xla::Literal::vec1(fim),
            xla::Literal::vec1(&[delta]),
            xla::Literal::vec1(&[lambda]),
            xla::Literal::vec1(cost),
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<i32>()?)
    }

    /// Execute the AOT dequant kernel on one padded chunk.
    pub fn dequant_chunk(&self, idx: &[i32], delta: f32) -> Result<Vec<f32>> {
        if idx.len() != KERNEL_N {
            return Err(Error::Config(format!(
                "dequant_chunk expects n={KERNEL_N}, got {}",
                idx.len()
            )));
        }
        let exe = self.executable("dequant")?;
        let args = [xla::Literal::vec1(idx), xla::Literal::vec1(&[delta])];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// RDOQ an arbitrary-length weight vector through the device kernel,
    /// padding the tail chunk (pad weights quantize to 0 and are dropped).
    pub fn rd_assign(
        &self,
        w: &[f32],
        fim: &[f32],
        delta: f32,
        lambda: f32,
        cost: &[f32],
    ) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(w.len());
        for (wc, fc) in w.chunks(KERNEL_N).zip(fim.chunks(KERNEL_N)) {
            if wc.len() == KERNEL_N {
                out.extend(self.rd_assign_chunk(wc, fc, delta, lambda, cost)?);
            } else {
                let mut wp = wc.to_vec();
                let mut fp = fc.to_vec();
                wp.resize(KERNEL_N, 0.0);
                fp.resize(KERNEL_N, 0.0);
                let chunk = self.rd_assign_chunk(&wp, &fp, delta, lambda, cost)?;
                out.extend(&chunk[..wc.len()]);
            }
        }
        Ok(out)
    }
}
