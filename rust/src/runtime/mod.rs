//! PJRT runtime: load AOT HLO-text artifacts (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`), evaluate
//! network accuracy, and run the Pallas RDOQ kernel from the Rust hot path.
//!
//!  * [`pjrt`]    — the engine + compile cache (thread-pinned).
//!  * [`eval`]    — the accuracy oracle over the `.nds` dataset.
//!  * [`service`] — channel-fronted runtime thread for multi-threaded
//!    coordinators (the engine is not `Send`).

pub mod eval;
pub mod pjrt;
pub mod service;

pub use eval::Evaluator;
pub use pjrt::{Engine, EVAL_BATCH, KERNEL_HALF, KERNEL_K, KERNEL_N};
pub use service::{EvalService, EvalServiceHost};
