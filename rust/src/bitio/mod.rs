//! Bit-level I/O: MSB-first bit writer/reader over byte buffers.
//!
//! Used by the Huffman-family codecs and the Exp-Golomb codec; the CABAC
//! engine has its own byte-oriented renormalization and does not go through
//! this layer.

/// MSB-first bit writer into an owned `Vec<u8>`.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.cur = (self.cur << 1) | bit as u8;
        self.nbits += 1;
        if self.nbits == 8 {
            self.buf.push(self.cur);
            self.cur = 0;
            self.nbits = 0;
        }
    }

    /// Write the lowest `n` bits of `v`, MSB first.
    #[inline]
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        for i in (0..n).rev() {
            self.put_bit((v >> i) & 1 == 1);
        }
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush (zero-padding the last byte) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.cur <<= 8 - self.nbits;
            self.buf.push(self.cur);
        }
        self.buf
    }
}

/// MSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    bit: u8,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0, bit: 0 }
    }

    /// Read one bit; reads past the end return `None`.
    #[inline]
    pub fn get_bit(&mut self) -> Option<bool> {
        if self.pos >= self.buf.len() {
            return None;
        }
        let b = (self.buf[self.pos] >> (7 - self.bit)) & 1 == 1;
        self.bit += 1;
        if self.bit == 8 {
            self.bit = 0;
            self.pos += 1;
        }
        Some(b)
    }

    /// Read `n` bits MSB-first into the low bits of a u64.
    #[inline]
    pub fn get_bits(&mut self, n: u32) -> Option<u64> {
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.get_bit()? as u64;
        }
        Some(v)
    }

    /// Bits consumed so far.
    pub fn bit_pos(&self) -> usize {
        self.pos * 8 + self.bit as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn single_bits_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.put_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.finish();
        assert_eq!(bytes.len(), 2);
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.get_bit(), Some(b));
        }
    }

    #[test]
    fn multi_bit_roundtrip() {
        let mut w = BitWriter::new();
        w.put_bits(0b1011, 4);
        w.put_bits(0xDEADBEEF, 32);
        w.put_bits(1, 1);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(4), Some(0b1011));
        assert_eq!(r.get_bits(32), Some(0xDEADBEEF));
        assert_eq!(r.get_bits(1), Some(1));
    }

    #[test]
    fn eof_returns_none() {
        let bytes = BitWriter::new().finish();
        assert!(bytes.is_empty());
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bit(), None);
    }

    #[test]
    fn randomized_roundtrip() {
        let mut rng = Pcg64::new(3);
        for _ in 0..50 {
            let mut w = BitWriter::new();
            let items: Vec<(u64, u32)> = (0..rng.below(200))
                .map(|_| {
                    let n = 1 + rng.below(40) as u32;
                    let v = rng.next_u64() & ((1u128 << n) - 1) as u64;
                    (v, n)
                })
                .collect();
            for &(v, n) in &items {
                w.put_bits(v, n);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(v, n) in &items {
                assert_eq!(r.get_bits(n), Some(v));
            }
        }
    }

    #[test]
    fn bit_len_tracks() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.put_bits(0, 13);
        assert_eq!(w.bit_len(), 13);
    }
}
