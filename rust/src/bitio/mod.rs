//! Bit-level I/O: MSB-first bit writer/reader over byte buffers.
//!
//! Used by the Huffman-family codecs and the Exp-Golomb codec; the CABAC
//! engine has its own byte-oriented renormalization and does not go through
//! this layer.
//!
//! Both sides are buffered: bits accumulate in a 64-bit register and move
//! to/from the byte buffer a 32-bit word at a time, instead of the seed's
//! bit-by-bit shifting.  The wire format is unchanged (plain MSB-first
//! bitstream, final byte zero-padded) — only the access pattern differs.

/// MSB-first bit writer into an owned `Vec<u8>`.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits, right-aligned: the low `nbits` bits of `acc` are the
    /// newest output, oldest at the high end.  Invariant: `nbits < 32`
    /// between chunks, so a ≤32-bit chunk always fits the register.
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append up to 32 bits already masked to width `n` (`nbits < 32` on
    /// entry, so `acc` never holds more than 63 bits before flushing).
    #[inline]
    fn push_chunk(&mut self, v: u64, n: u32) {
        self.acc = (self.acc << n) | v;
        self.nbits += n;
        if self.nbits >= 32 {
            self.nbits -= 32;
            let word = (self.acc >> self.nbits) as u32;
            self.buf.extend_from_slice(&word.to_be_bytes());
        }
    }

    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.push_chunk(bit as u64, 1);
    }

    /// Write the lowest `n` bits of `v`, MSB first.
    #[inline]
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        if n > 32 {
            self.push_chunk((v >> 32) & ((1u64 << (n - 32)) - 1), n - 32);
            self.push_chunk(v & 0xFFFF_FFFF, 32);
        } else if n > 0 {
            let mask = if n == 32 { u32::MAX as u64 } else { (1u64 << n) - 1 };
            self.push_chunk(v & mask, n);
        }
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush (zero-padding the last byte) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.buf.push((self.acc >> self.nbits) as u8);
        }
        if self.nbits > 0 {
            let tail = ((self.acc << (8 - self.nbits)) & 0xFF) as u8;
            self.buf.push(tail);
        }
        self.buf
    }
}

/// MSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next unread byte offset (everything before it is in `acc`).
    pos: usize,
    /// Refill register: the low `have` bits of `acc` are unconsumed input,
    /// oldest at the high end.
    acc: u64,
    have: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self {
            buf,
            pos: 0,
            acc: 0,
            have: 0,
        }
    }

    /// Top the register up to > 56 bits (or to end of input), pulling
    /// 32-bit words while they fit.
    #[inline]
    fn refill(&mut self) {
        while self.have <= 56 && self.pos < self.buf.len() {
            if self.have <= 32 && self.pos + 4 <= self.buf.len() {
                let word =
                    u32::from_be_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
                self.acc = (self.acc << 32) | word as u64;
                self.have += 32;
                self.pos += 4;
            } else {
                self.acc = (self.acc << 8) | self.buf[self.pos] as u64;
                self.have += 8;
                self.pos += 1;
            }
        }
    }

    #[inline]
    fn remaining_bits(&self) -> usize {
        (self.buf.len() - self.pos) * 8 + self.have as usize
    }

    /// Read one bit; reads past the end return `None`.
    #[inline]
    pub fn get_bit(&mut self) -> Option<bool> {
        if self.have == 0 {
            self.refill();
            if self.have == 0 {
                return None;
            }
        }
        self.have -= 1;
        Some((self.acc >> self.have) & 1 == 1)
    }

    /// Read `n` bits MSB-first into the low bits of a u64.  A read past the
    /// end returns `None` and exhausts the reader.
    #[inline]
    pub fn get_bits(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 64);
        if self.remaining_bits() < n as usize {
            // Match the seed semantics: a failed multi-bit read consumes
            // the tail, so every later read also reports end-of-stream.
            self.pos = self.buf.len();
            self.have = 0;
            return None;
        }
        let mut v = 0u64;
        let mut need = n;
        while need > 0 {
            if self.have == 0 {
                self.refill();
            }
            let take = need.min(self.have).min(32);
            self.have -= take;
            v = (v << take) | ((self.acc >> self.have) & ((1u64 << take) - 1));
            need -= take;
        }
        Some(v)
    }

    /// Bits consumed so far.
    pub fn bit_pos(&self) -> usize {
        self.pos * 8 - self.have as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn single_bits_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.put_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.finish();
        assert_eq!(bytes.len(), 2);
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.get_bit(), Some(b));
        }
    }

    #[test]
    fn multi_bit_roundtrip() {
        let mut w = BitWriter::new();
        w.put_bits(0b1011, 4);
        w.put_bits(0xDEADBEEF, 32);
        w.put_bits(1, 1);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(4), Some(0b1011));
        assert_eq!(r.get_bits(32), Some(0xDEADBEEF));
        assert_eq!(r.get_bits(1), Some(1));
    }

    #[test]
    fn eof_returns_none() {
        let bytes = BitWriter::new().finish();
        assert!(bytes.is_empty());
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bit(), None);
    }

    #[test]
    fn randomized_roundtrip() {
        let mut rng = Pcg64::new(3);
        for _ in 0..50 {
            let mut w = BitWriter::new();
            let items: Vec<(u64, u32)> = (0..rng.below(200))
                .map(|_| {
                    let n = 1 + rng.below(40) as u32;
                    let v = rng.next_u64() & ((1u128 << n) - 1) as u64;
                    (v, n)
                })
                .collect();
            for &(v, n) in &items {
                w.put_bits(v, n);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(v, n) in &items {
                assert_eq!(r.get_bits(n), Some(v));
            }
        }
    }

    #[test]
    fn bit_len_tracks() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.put_bits(0, 13);
        assert_eq!(w.bit_len(), 13);
    }

    #[test]
    fn full_width_64_bit_writes() {
        let mut w = BitWriter::new();
        w.put_bits(u64::MAX, 64);
        w.put_bits(0x0123_4567_89AB_CDEF, 64);
        w.put_bit(true);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(64), Some(u64::MAX));
        assert_eq!(r.get_bits(64), Some(0x0123_4567_89AB_CDEF));
        assert_eq!(r.get_bit(), Some(true));
    }

    #[test]
    fn wire_format_is_plain_msb_first() {
        // The buffered writer must keep the seed's byte layout: bits land
        // MSB-first, last byte zero-padded.
        let mut w = BitWriter::new();
        w.put_bits(0b1, 1);
        w.put_bits(0b0110, 4);
        w.put_bits(0b101, 3);
        w.put_bits(0xAB, 8);
        w.put_bit(true);
        assert_eq!(w.finish(), vec![0b1011_0101, 0xAB, 0b1000_0000]);
    }

    #[test]
    fn failed_read_exhausts_reader() {
        let bytes = vec![0xFF];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(4), Some(0xF));
        assert_eq!(r.get_bits(16), None); // only 4 bits left
        assert_eq!(r.get_bit(), None);
    }

    #[test]
    fn bit_pos_counts_through_refills() {
        let bytes: Vec<u8> = (0..16).collect();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.bit_pos(), 0);
        r.get_bits(7).unwrap();
        assert_eq!(r.bit_pos(), 7);
        r.get_bits(64).unwrap();
        assert_eq!(r.bit_pos(), 71);
        r.get_bit().unwrap();
        assert_eq!(r.bit_pos(), 72);
    }
}
