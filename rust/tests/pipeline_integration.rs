#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! End-to-end pipeline integration over the real artifacts: grid-search on
//! the trained LeNet5, verifying (a) the Table I orderings hold, (b) the
//! best DC result decodes losslessly to the evaluated network, (c) the eval
//! service survives concurrent use.
//!
//! Skipped (not failed) when artifacts are absent.

use std::path::PathBuf;

use deepcabac::coordinator::{self, Method, SearchConfig};
use deepcabac::model::{read_nwf, CompressedNetwork, Importance};
use deepcabac::runtime::EvalService;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("MANIFEST.txt").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn quick_cfg() -> SearchConfig {
    SearchConfig {
        dc1_lambdas: 4,
        dc2_deltas: 12,
        dc2_keep: 3,
        dc2_lambdas: 4,
        lloyd_lambdas: 3,
        lloyd_clusters: &[64],
        uniform_clusters: &[64, 256],
        ..SearchConfig::default()
    }
}

#[test]
fn full_search_reproduces_table1_shape_on_lenet5_sparse() {
    let Some(art) = artifacts() else { return };
    let host = EvalService::spawn(art.clone(), art.join("dataset.nds"), 4).unwrap();
    let net = read_nwf(art.join("lenet5_sparse.nwf")).unwrap();
    let cfg = quick_cfg();

    let dc2 = coordinator::search(&net, Method::DcV2, &cfg, &host.handle).unwrap();
    let uni = coordinator::search(&net, Method::Uniform, &cfg, &host.handle).unwrap();

    let dc2_best = dc2.best_result().expect("DC-v2 found no feasible point");
    let uni_best = uni.best_result().expect("Uniform found no feasible point");

    // Both feasible points hold the tolerance.
    assert!(dc2_best.accuracy >= dc2.original_accuracy - cfg.tolerance);
    assert!(uni_best.accuracy >= uni.original_accuracy - cfg.tolerance);
    // The paper's headline ordering: DeepCABAC compresses harder than
    // uniform+best-of-lossless at iso-accuracy.
    assert!(
        dc2_best.percent() < uni_best.percent(),
        "DC-v2 {:.2}% !< Uniform {:.2}%",
        dc2_best.percent(),
        uni_best.percent()
    );
    // Sparse model at <=0.5pp: must compress to well under 10% of f32.
    assert!(dc2_best.percent() < 10.0, "{:.2}%", dc2_best.percent());
}

#[test]
fn dc_best_candidate_decodes_losslessly() {
    let Some(art) = artifacts() else { return };
    let host = EvalService::spawn(art.clone(), art.join("dataset.nds"), 4).unwrap();
    let net = read_nwf(art.join("lenet5.nwf")).unwrap();
    let cfg = quick_cfg();
    let out = coordinator::search(&net, Method::DcV2, &cfg, &host.handle).unwrap();
    let best = out.best_result().unwrap();

    // Re-run the exact candidate and check the encode->decode identity.
    let compressed = coordinator::pipeline::compress_dc(&net, &best.candidate, &cfg);
    let bytes = compressed.to_bytes();
    let decoded = CompressedNetwork::from_bytes(&bytes).unwrap();
    for (a, b) in compressed.layers.iter().zip(&decoded.layers) {
        assert_eq!(a.ints, b.ints);
        assert_eq!(a.delta, b.delta);
        assert_eq!(a.bias, b.bias);
    }
    // And its accuracy matches what the search recorded.
    let acc = host.handle.accuracy(&decoded.reconstruct(&net.name)).unwrap();
    assert!((acc - best.accuracy).abs() < 1e-9);
}

#[test]
fn lloyd_importance_variants_run() {
    let Some(art) = artifacts() else { return };
    let host = EvalService::spawn(art.clone(), art.join("dataset.nds"), 4).unwrap();
    let net = read_nwf(art.join("lenet5.nwf")).unwrap();
    let cfg = quick_cfg();
    for imp in [Importance::Ones, Importance::Fisher, Importance::Hessian] {
        let out = coordinator::search(&net, Method::Lloyd(imp), &cfg, &host.handle).unwrap();
        assert!(!out.results.is_empty());
        // All results carry a real backend name and plausible sizes.
        for r in &out.results {
            assert!(["scalar-Huffman", "CSR-Huffman", "bzip2"].contains(&r.backend));
            assert!(r.sizes.compressed_weights > 0);
            assert!(r.percent() < 120.0);
        }
    }
}

#[test]
fn eval_service_handles_concurrent_clients() {
    let Some(art) = artifacts() else { return };
    let host = EvalService::spawn(art.clone(), art.join("dataset.nds"), 2).unwrap();
    let net = read_nwf(art.join("lenet300.nwf")).unwrap();
    let base = host.handle.accuracy(&net).unwrap();
    std::thread::scope(|s| {
        for _ in 0..6 {
            let h = host.handle.clone();
            let n = net.clone();
            s.spawn(move || {
                let acc = h.accuracy(&n).unwrap();
                assert_eq!(acc, base); // deterministic graph, same input
            });
        }
    });
}

#[test]
fn device_kernel_pipeline_close_to_host() {
    // The L1-Pallas compression path must land within a few percent of the
    // host RDOQ path in size and within tolerance in accuracy on *sparse*
    // models (its target regime — see compress_dc_device's doc: one frozen
    // table per layer cannot follow the encoder's per-weight context
    // switching, which costs ~30% on dense planes but single digits on
    // sparse ones).
    let Some(art) = artifacts() else { return };
    let host_svc = EvalService::spawn(art.clone(), art.join("dataset.nds"), 2).unwrap();
    let net = read_nwf(art.join("lenet300_sparse.nwf")).unwrap();
    let cfg = quick_cfg();
    let cand = deepcabac::coordinator::Candidate {
        method: Method::DcV2,
        s: 0.0,
        delta: 0.02,
        lambda: 1.0,
        clusters: 0,
    };
    let host = coordinator::pipeline::compress_dc(&net, &cand, &cfg).to_bytes();
    let device = coordinator::pipeline::compress_dc_device(&net, &cand, &cfg, &host_svc.handle)
        .unwrap()
        .to_bytes();
    let rel = (device.len() as f64 - host.len() as f64).abs() / host.len() as f64;
    assert!(rel < 0.10, "host {} vs device {} ({rel:.3})", host.len(), device.len());
    let d_acc = host_svc
        .handle
        .accuracy(&CompressedNetwork::from_bytes(&device).unwrap().reconstruct_named())
        .unwrap();
    let h_acc = host_svc
        .handle
        .accuracy(&CompressedNetwork::from_bytes(&host).unwrap().reconstruct_named())
        .unwrap();
    assert!((d_acc - h_acc).abs() < 0.01, "host {h_acc} device {d_acc}");
}

#[test]
fn service_reports_missing_artifacts_gracefully() {
    let bad = std::env::temp_dir().join("dcb_no_artifacts");
    std::fs::create_dir_all(&bad).unwrap();
    // Engine::new succeeds (lazy artifact loading) but dataset load fails,
    // which must surface as an Err from spawn — not a panic.
    let r = EvalService::spawn(bad.clone(), bad.join("nope.nds"), 2);
    assert!(r.is_err());
}

#[test]
fn device_kernel_path_available_through_service() {
    let Some(art) = artifacts() else { return };
    let host = EvalService::spawn(art.clone(), art.join("dataset.nds"), 2).unwrap();
    let w = vec![0.05f32; 100];
    let fim = vec![1.0f32; 100];
    let cost = vec![1.0f32; deepcabac::runtime::KERNEL_K];
    let out = host.handle.rd_assign(&w, &fim, 0.01, 0.0, &cost).unwrap();
    assert_eq!(out.len(), 100);
    assert!(out.iter().all(|&i| i == 5)); // NN of 0.05/0.01
}
