//! Golden-vector fixtures: checked-in v1/v2/v3 `.dcb` streams that pin all
//! three container wire formats byte-for-byte.
//!
//! The fixtures under `rust/tests/fixtures/golden/` were produced by
//! `gen_golden.py` (a transcription of this crate's coder, self-verified by
//! an independent Python decoder before writing).  These tests prove the
//! compatibility story instead of asserting it in prose:
//!
//! * every fixture **decodes** to the expected network (derived from the
//!   same tiny LCG the generator uses), through the version-dispatched
//!   `CompressedNetwork::from_bytes` path;
//! * re-encoding the decoded network under the fixture's own policy is
//!   **byte-exact** — v1/v2 via the retained legacy bin format, v3 via the
//!   bypass fast path — so none of the three formats can silently drift.

use std::path::PathBuf;

use deepcabac::cabac::CodingConfig;
use deepcabac::model::{
    probe, CompressedNetwork, ContainerPolicy, Kind, QuantizedLayer, VERSION_V1, VERSION_V2,
    VERSION_V3,
};

const SLICE_LEN: usize = 512;

/// The generator's LCG, verbatim (gen_golden.py `class Lcg`).
struct Lcg {
    s: u64,
}

impl Lcg {
    fn new(seed: u64) -> Self {
        Self { s: seed }
    }

    fn next(&mut self) -> u64 {
        self.s = self
            .s
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.s >> 33
    }
}

fn gen_ints(lcg: &mut Lcg, count: usize, mag_cap: u64) -> Vec<i32> {
    (0..count)
        .map(|_| {
            if lcg.next() % 10 < 6 {
                0
            } else {
                let mag = (lcg.next() % mag_cap) as i32 + 1;
                if lcg.next() & 1 == 1 {
                    -mag
                } else {
                    mag
                }
            }
        })
        .collect()
}

/// The fixture network (gen_golden.py `golden_network`), re-derived here so
/// the expected symbols never live in two places.
fn golden_network() -> CompressedNetwork {
    let mut lcg = Lcg::new(0xDCB3);
    let fc1_ints = gen_ints(&mut lcg, 2000, 35);
    let fc1_bias: Vec<f32> = (0..40)
        .map(|_| ((lcg.next() % 64) as i64 - 32) as f32 / 16.0)
        .collect();
    let big_ints = gen_ints(&mut lcg, 1500, 250_000);
    CompressedNetwork {
        name: "golden_net".into(),
        cfg: CodingConfig::default(),
        layers: vec![
            QuantizedLayer {
                name: "fc1".into(),
                kind: Kind::Dense,
                shape: vec![50, 40],
                rows: 40,
                cols: 50,
                ints: fc1_ints,
                delta: 0.03125,
                bias: Some(fc1_bias),
            },
            QuantizedLayer {
                name: "big".into(),
                kind: Kind::Conv,
                shape: vec![50, 30],
                rows: 30,
                cols: 50,
                ints: big_ints,
                delta: 0.0078125,
                bias: None,
            },
        ],
    }
}

fn fixture(name: &str) -> Vec<u8> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/golden")
        .join(name);
    std::fs::read(&path).unwrap_or_else(|e| panic!("missing fixture {path:?}: {e}"))
}

fn policy(version: u8) -> ContainerPolicy {
    match version {
        VERSION_V1 => ContainerPolicy {
            version: VERSION_V1,
            slice_len: 0,
            threads: 1,
        },
        VERSION_V2 => ContainerPolicy::v2(SLICE_LEN, 2),
        _ => ContainerPolicy::v3(SLICE_LEN, 2),
    }
}

fn check_golden(file: &str, version: u8) {
    let raw = fixture(file);
    let expected = golden_network();

    let header = probe(&raw).unwrap_or_else(|e| panic!("{file}: probe failed: {e}"));
    assert_eq!(header.version, version, "{file}");
    assert_eq!(header.param_count(), expected.param_count(), "{file}");

    // Decode through the version-dispatched path, single- and multi-thread.
    for threads in [1usize, 4] {
        let got = CompressedNetwork::from_bytes_with(&raw, threads)
            .unwrap_or_else(|e| panic!("{file}: decode failed: {e}"));
        assert_eq!(got.name, expected.name, "{file}");
        assert_eq!(got.cfg, expected.cfg, "{file}");
        assert_eq!(got.layers, expected.layers, "{file} threads={threads}");
    }

    // Re-encode byte-exact under the fixture's own policy.
    let reencoded = expected.to_bytes_with(policy(version));
    assert_eq!(
        reencoded, raw,
        "{file}: re-encode is not byte-exact (wire format drifted — if this \
         was intentional, bump the container version instead of changing an \
         existing format, and regenerate via gen_golden.py)"
    );
}

#[test]
fn golden_v1_decodes_and_reencodes_byte_exact() {
    check_golden("golden_v1.dcb", VERSION_V1);
}

#[test]
fn golden_v2_decodes_and_reencodes_byte_exact() {
    check_golden("golden_v2.dcb", VERSION_V2);
}

#[test]
fn golden_v3_decodes_and_reencodes_byte_exact() {
    check_golden("golden_v3.dcb", VERSION_V3);
}

#[test]
fn golden_network_exercises_wide_batched_suffixes() {
    // The fixture must cover EG suffixes wider than one 16-bit bypass
    // chunk, so the batched path's chunk split is pinned by the vectors.
    let net = golden_network();
    let n = net.cfg.max_abs_gr;
    let widest = net.layers[1]
        .ints
        .iter()
        .filter(|v| v.unsigned_abs() > n)
        .map(|v| 31 - (v.unsigned_abs() - n).leading_zeros())
        .max()
        .unwrap();
    assert!(widest > 16, "widest suffix k = {widest}");
}

#[test]
fn golden_fixtures_all_decode_to_the_same_network() {
    let a = CompressedNetwork::from_bytes(&fixture("golden_v1.dcb")).unwrap();
    let b = CompressedNetwork::from_bytes(&fixture("golden_v2.dcb")).unwrap();
    let c = CompressedNetwork::from_bytes(&fixture("golden_v3.dcb")).unwrap();
    assert_eq!(a.layers, b.layers);
    assert_eq!(b.layers, c.layers);
}
