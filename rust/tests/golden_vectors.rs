#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Golden-vector fixtures: checked-in v1/v2/v3 `.dcb` streams that pin all
//! three container wire formats byte-for-byte.
//!
//! The fixtures under `rust/tests/fixtures/golden/` were produced by
//! `gen_golden.py` (a transcription of this crate's coder, self-verified by
//! an independent Python decoder before writing).  These tests prove the
//! compatibility story instead of asserting it in prose:
//!
//! * every fixture **decodes** to the expected network (derived from the
//!   same tiny LCG the generator uses), through the version-dispatched
//!   `CompressedNetwork::from_bytes` path;
//! * re-encoding the decoded network under the fixture's own policy is
//!   **byte-exact** — v1/v2 via the retained legacy bin format, v3 via the
//!   bypass fast path — so none of the three formats can silently drift;
//! * the DCB4 delta fixture (`golden_v4.dcb` onto `golden_v4_base.dcb`)
//!   decodes, re-encodes byte-exact, applies bit-identically through the
//!   fused and eager paths, and **rejects** a wrong base (CRC), a
//!   tampered shape key, and a truncated skip-flag table.

use std::path::PathBuf;

use deepcabac::cabac::CodingConfig;
use deepcabac::model::{
    apply_delta_network_into, delta_header, probe, CompressedDelta, CompressedNetwork,
    ContainerPolicy, DecodeArena, DeltaLayer, Kind, QuantizedLayer, VERSION_V1, VERSION_V2,
    VERSION_V3, VERSION_V4,
};
use deepcabac::util::{crc32, Error};

const SLICE_LEN: usize = 512;

/// The generator's LCG, verbatim (gen_golden.py `class Lcg`).
struct Lcg {
    s: u64,
}

impl Lcg {
    fn new(seed: u64) -> Self {
        Self { s: seed }
    }

    fn next(&mut self) -> u64 {
        self.s = self
            .s
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.s >> 33
    }
}

fn gen_ints(lcg: &mut Lcg, count: usize, mag_cap: u64) -> Vec<i32> {
    (0..count)
        .map(|_| {
            if lcg.next() % 10 < 6 {
                0
            } else {
                let mag = (lcg.next() % mag_cap) as i32 + 1;
                if lcg.next() & 1 == 1 {
                    -mag
                } else {
                    mag
                }
            }
        })
        .collect()
}

/// The fixture network (gen_golden.py `golden_network`), re-derived here so
/// the expected symbols never live in two places.
fn golden_network() -> CompressedNetwork {
    let mut lcg = Lcg::new(0xDCB3);
    let fc1_ints = gen_ints(&mut lcg, 2000, 35);
    let fc1_bias: Vec<f32> = (0..40)
        .map(|_| ((lcg.next() % 64) as i64 - 32) as f32 / 16.0)
        .collect();
    let big_ints = gen_ints(&mut lcg, 1500, 250_000);
    CompressedNetwork {
        name: "golden_net".into(),
        cfg: CodingConfig::default(),
        layers: vec![
            QuantizedLayer {
                name: "fc1".into(),
                kind: Kind::Dense,
                shape: vec![50, 40],
                rows: 40,
                cols: 50,
                ints: fc1_ints,
                delta: 0.03125,
                bias: Some(fc1_bias),
            },
            QuantizedLayer {
                name: "big".into(),
                kind: Kind::Conv,
                shape: vec![50, 30],
                rows: 30,
                cols: 50,
                ints: big_ints,
                delta: 0.0078125,
                bias: None,
            },
        ],
    }
}

fn fixture(name: &str) -> Vec<u8> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/golden")
        .join(name);
    std::fs::read(&path).unwrap_or_else(|e| panic!("missing fixture {path:?}: {e}"))
}

fn policy(version: u8) -> ContainerPolicy {
    match version {
        VERSION_V1 => ContainerPolicy {
            threads: 1,
            ..ContainerPolicy::v1()
        },
        VERSION_V2 => ContainerPolicy::v2(SLICE_LEN, 2),
        _ => ContainerPolicy::v3(SLICE_LEN, 2),
    }
}

fn check_golden(file: &str, version: u8) {
    let raw = fixture(file);
    let expected = golden_network();

    let header = probe(&raw).unwrap_or_else(|e| panic!("{file}: probe failed: {e}"));
    assert_eq!(header.version, version, "{file}");
    assert_eq!(header.param_count(), expected.param_count(), "{file}");

    // Decode through the version-dispatched path, single- and multi-thread.
    for threads in [1usize, 4] {
        let got = CompressedNetwork::from_bytes_with(&raw, threads)
            .unwrap_or_else(|e| panic!("{file}: decode failed: {e}"));
        assert_eq!(got.name, expected.name, "{file}");
        assert_eq!(got.cfg, expected.cfg, "{file}");
        assert_eq!(got.layers, expected.layers, "{file} threads={threads}");
    }

    // Re-encode byte-exact under the fixture's own policy.
    let reencoded = expected.to_bytes_with(policy(version));
    assert_eq!(
        reencoded, raw,
        "{file}: re-encode is not byte-exact (wire format drifted — if this \
         was intentional, bump the container version instead of changing an \
         existing format, and regenerate via gen_golden.py)"
    );
}

#[test]
fn golden_v1_decodes_and_reencodes_byte_exact() {
    check_golden("golden_v1.dcb", VERSION_V1);
}

#[test]
fn golden_v2_decodes_and_reencodes_byte_exact() {
    check_golden("golden_v2.dcb", VERSION_V2);
}

#[test]
fn golden_v3_decodes_and_reencodes_byte_exact() {
    check_golden("golden_v3.dcb", VERSION_V3);
}

/// The v4 base network (gen_golden.py `golden_v4_base_network`): a
/// fresh-seed sibling of `golden_network` with the same geometry family.
fn golden_v4_base_network() -> CompressedNetwork {
    let mut lcg = Lcg::new(0xDCB4);
    let fc1_ints = gen_ints(&mut lcg, 2000, 35);
    let fc1_bias: Vec<f32> = (0..40)
        .map(|_| ((lcg.next() % 64) as i64 - 32) as f32 / 16.0)
        .collect();
    let big_ints = gen_ints(&mut lcg, 1500, 250_000);
    CompressedNetwork {
        name: "golden_base".into(),
        cfg: CodingConfig::default(),
        layers: vec![
            QuantizedLayer {
                name: "fc1".into(),
                kind: Kind::Dense,
                shape: vec![50, 40],
                rows: 40,
                cols: 50,
                ints: fc1_ints,
                delta: 0.03125,
                bias: Some(fc1_bias),
            },
            QuantizedLayer {
                name: "big".into(),
                kind: Kind::Conv,
                shape: vec![50, 30],
                rows: 30,
                cols: 50,
                ints: big_ints,
                delta: 0.0078125,
                bias: None,
            },
        ],
    }
}

/// Sparse residual plane (gen_golden.py `gen_residual`): ~10% nonzero,
/// magnitudes 1..=4.
fn gen_residual(lcg: &mut Lcg, count: usize, mag_cap: u64) -> Vec<i32> {
    (0..count)
        .map(|_| {
            if lcg.next() % 10 == 0 {
                let mag = (lcg.next() % mag_cap) as i32 + 1;
                if lcg.next() & 1 == 1 {
                    -mag
                } else {
                    mag
                }
            } else {
                0
            }
        })
        .collect()
}

/// The expected delta (gen_golden.py `golden_v4_delta`), pinned against
/// the checked-in base fixture bytes: fc1 coded, big skipped.
fn golden_delta(base_raw: &[u8]) -> CompressedDelta {
    let base = golden_v4_base_network();
    let mut lcg = Lcg::new(0xDCB5);
    let fc1 = &base.layers[0];
    let big = &base.layers[1];
    CompressedDelta {
        name: base.name.clone(),
        cfg: base.cfg,
        base_crc32: crc32(base_raw),
        base_shape_key: probe(base_raw).unwrap().shape_key(),
        layers: vec![
            DeltaLayer {
                name: fc1.name.clone(),
                kind: fc1.kind,
                shape: fc1.shape.clone(),
                rows: fc1.rows,
                cols: fc1.cols,
                delta: 0.015625,
                bias: None,
                residual: Some(gen_residual(&mut lcg, fc1.rows * fc1.cols, 4)),
            },
            DeltaLayer {
                name: big.name.clone(),
                kind: big.kind,
                shape: big.shape.clone(),
                rows: big.rows,
                cols: big.cols,
                delta: 0.0,
                bias: None,
                residual: None,
            },
        ],
    }
}

/// Re-stamp a tampered container body with a valid trailing CRC, so the
/// negative tests hit the semantic check they target rather than the
/// outer CRC gate.
fn restamp_crc(raw: &mut Vec<u8>) {
    let body_end = raw.len() - 4;
    let crc = crc32(&raw[4..body_end]);
    raw[body_end..].copy_from_slice(&crc.to_le_bytes());
}

#[test]
fn golden_v4_base_decodes_and_reencodes_byte_exact() {
    let raw = fixture("golden_v4_base.dcb");
    let expected = golden_v4_base_network();
    let header = probe(&raw).unwrap();
    assert_eq!(header.version, VERSION_V3);
    for threads in [1usize, 4] {
        let got = CompressedNetwork::from_bytes_with(&raw, threads).unwrap();
        assert_eq!(got.name, expected.name);
        assert_eq!(got.layers, expected.layers, "threads={threads}");
    }
    assert_eq!(expected.to_bytes_with(ContainerPolicy::v3(SLICE_LEN, 2)), raw);
}

#[test]
fn golden_v4_decodes_and_reencodes_byte_exact() {
    let base_raw = fixture("golden_v4_base.dcb");
    let raw = fixture("golden_v4.dcb");
    let expected = golden_delta(&base_raw);

    let header = probe(&raw).unwrap();
    assert_eq!(header.version, VERSION_V4);
    assert_eq!(header.delta, Some(expected.header()));
    assert_eq!(
        header.layers.iter().map(|l| l.skipped).collect::<Vec<_>>(),
        vec![false, true]
    );
    assert_eq!(delta_header(&raw).unwrap(), expected.header());

    for threads in [1usize, 4] {
        let got = CompressedDelta::from_bytes_with(&raw, threads).unwrap();
        assert_eq!(got.name, expected.name);
        assert_eq!(got.cfg, expected.cfg);
        assert_eq!(got.base_crc32, expected.base_crc32);
        assert_eq!(got.base_shape_key, expected.base_shape_key);
        assert_eq!(got.layers, expected.layers, "threads={threads}");
    }
    assert_eq!(
        expected.to_bytes_with(ContainerPolicy::v3(SLICE_LEN, 2)),
        raw,
        "golden_v4.dcb: re-encode is not byte-exact (delta wire format \
         drifted — bump the container version instead, and regenerate via \
         gen_golden.py)"
    );
}

#[test]
fn golden_v4_fused_apply_matches_eager_bit_exact() {
    let base_raw = fixture("golden_v4_base.dcb");
    let raw = fixture("golden_v4.dcb");
    let eager = golden_delta(&base_raw)
        .apply_to(&golden_v4_base_network().reconstruct_named())
        .unwrap();
    let mut arena = DecodeArena::new();
    for threads in [1usize, 4] {
        let fused = apply_delta_network_into(&base_raw, &raw, threads, &mut arena).unwrap();
        for (f, e) in fused.layers.iter().zip(&eager.layers) {
            let fb: Vec<u32> = f.weights.iter().map(|w| w.to_bits()).collect();
            let eb: Vec<u32> = e.weights.iter().map(|w| w.to_bits()).collect();
            assert_eq!(fb, eb, "layer {} threads {threads}", f.name);
            assert_eq!(f.bias, e.bias);
        }
    }
}

#[test]
fn golden_v4_rejects_wrong_base_crc() {
    // golden_v3.dcb has different bytes AND different geometry; the CRC
    // gate must fire first (defense order: identity before shape).
    let raw = fixture("golden_v4.dcb");
    let wrong_base = fixture("golden_v3.dcb");
    let mut arena = DecodeArena::new();
    let err = apply_delta_network_into(&wrong_base, &raw, 2, &mut arena).unwrap_err();
    assert!(matches!(err, Error::Crc(_)), "{err}");
    // the error names both sides: the CRC the delta pinned and what the
    // offered base bytes actually hash to
    let msg = err.to_string();
    let pinned = format!("{:08x}", delta_header(&raw).unwrap().base_crc32);
    let actual = format!("{:08x}", crc32(&wrong_base));
    assert!(msg.contains(&pinned), "missing pinned crc {pinned}: {msg}");
    assert!(msg.contains(&actual), "missing actual crc {actual}: {msg}");
}

#[test]
fn golden_v4_rejects_tampered_shape_key() {
    let base_raw = fixture("golden_v4_base.dcb");
    let mut raw = fixture("golden_v4.dcb");
    // base_shape_key sits after magic(4) + version(1) + name_len(2) +
    // name + max_abs_gr(4) + eg_contexts(4) + base_crc32(4).
    let name_len = u16::from_le_bytes([raw[5], raw[6]]) as usize;
    let off = 4 + 1 + 2 + name_len + 4 + 4 + 4;
    raw[off] ^= 0xFF;
    restamp_crc(&mut raw);
    // the delta itself still parses; only the base linkage is broken
    let hdr = delta_header(&raw).unwrap();
    assert_eq!(hdr.base_crc32, crc32(&base_raw));
    let mut arena = DecodeArena::new();
    let err = apply_delta_network_into(&base_raw, &raw, 2, &mut arena).unwrap_err();
    assert!(matches!(err, Error::ShapeMismatch(_)), "{err}");
    // the error names both keys: the (tampered) one the delta expects and
    // the one the offered base actually has
    let msg = err.to_string();
    let expected_key = format!("{:016x}", delta_header(&raw).unwrap().base_shape_key);
    let actual_key = format!("{:016x}", probe(&base_raw).unwrap().shape_key());
    assert!(msg.contains(&expected_key), "missing tampered key {expected_key}: {msg}");
    assert!(msg.contains(&actual_key), "missing base key {actual_key}: {msg}");
}

#[test]
fn golden_v4_rejects_truncated_skip_table() {
    let raw = fixture("golden_v4.dcb");
    let name_len = u16::from_le_bytes([raw[5], raw[6]]) as usize;
    // keep the head through n_layers, drop the skip-flag table (and all
    // layers) — then re-stamp the CRC so the wire check is what fires
    let keep = 4 + 1 + 2 + name_len + 4 + 4 + 4 + 8 + 4;
    let mut truncated = raw[..keep].to_vec();
    truncated.extend([0u8; 4]);
    restamp_crc(&mut truncated);
    let err = probe(&truncated).unwrap_err();
    assert!(matches!(err, Error::Wire(_)), "{err}");
}

#[test]
fn golden_network_exercises_wide_batched_suffixes() {
    // The fixture must cover EG suffixes wider than one 16-bit bypass
    // chunk, so the batched path's chunk split is pinned by the vectors.
    let net = golden_network();
    let n = net.cfg.max_abs_gr;
    let widest = net.layers[1]
        .ints
        .iter()
        .filter(|v| v.unsigned_abs() > n)
        .map(|v| 31 - (v.unsigned_abs() - n).leading_zeros())
        .max()
        .unwrap();
    assert!(widest > 16, "widest suffix k = {widest}");
}

#[test]
fn golden_fixtures_all_decode_to_the_same_network() {
    let a = CompressedNetwork::from_bytes(&fixture("golden_v1.dcb")).unwrap();
    let b = CompressedNetwork::from_bytes(&fixture("golden_v2.dcb")).unwrap();
    let c = CompressedNetwork::from_bytes(&fixture("golden_v3.dcb")).unwrap();
    assert_eq!(a.layers, b.layers);
    assert_eq!(b.layers, c.layers);
}
