#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Encode-side fuzz + differential round-trip campaign — the mirror image
//! of `fault_injection.rs` for the ingest→quantize→encode path.
//!
//! The contract under test: feeding **any** input to the hardened encode
//! entry points produces either a typed [`Error`] or a container that
//! round-trips bit-exactly — never a panic escape, never allocation beyond
//! the 128 MiB cap.  Three layers of attack:
//!
//! * an **exhaustive single-byte corruption sweep** over the committed
//!   `golden.nwf` fixture (generated + self-verified by `gen_golden.py`),
//!   each flipped byte tried as-is (the CRC gate's job) and CRC-restamped
//!   (penetrating to the header/budget validation behind the gate); any
//!   mutation the parser *accepts* must still encode cleanly — the
//!   differential half of the campaign;
//! * a **seeded adversarial-network campaign**
//!   ([`deepcabac::testutil::fuzz::NetGen`]) of NaN/±Inf/subnormal/−0.0
//!   salted planes and pathological shapes, driven through every
//!   [`NonFinitePolicy`] — `Reject` must fail typed exactly when the
//!   network is dirty, `Sanitize`/`Clamp` must always produce a
//!   byte-stable container whose fused and two-pass decodes agree
//!   bit-for-bit;
//! * a **counting allocator** asserting every attempt stays far below the
//!   cap — a corrupted `rows` field that slipped past the ingest budget
//!   would show up here as a multi-gigabyte allocation.
//!
//! Debug builds stride-sample the sweep; release builds (CI encode-fuzz
//! step, `DCB_FUZZ_ITERS=1024`) sweep every byte.

use std::alloc::{GlobalAlloc, Layout, System};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use deepcabac::coordinator::pipeline::compress_dc_policy;
use deepcabac::coordinator::{Candidate, Method, SearchConfig};
use deepcabac::model::{
    decode_network_into, parse_nwf, CompressedNetwork, ContainerPolicy, DecodeArena, IngestLimits,
    Network, NonFinitePolicy,
};
use deepcabac::testutil::fuzz::{flip_bit, restamp, NetGen};
use deepcabac::util::Error;

struct CountingAlloc;

static ALLOC_BYTES: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_BYTES.fetch_add(new_size, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Per-attempt allocation ceiling (matches the decode-side harness).
const ALLOC_CAP_BYTES: usize = 128 << 20;

fn fixture(name: &str) -> Vec<u8> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/golden")
        .join(name);
    std::fs::read(&path).unwrap_or_else(|e| panic!("missing fixture {path:?}: {e}"))
}

/// Debug builds sample every 7th byte; release sweeps exhaustively.
fn sweep_stride() -> usize {
    if cfg!(debug_assertions) {
        7
    } else {
        1
    }
}

/// Tight budget for the 2.6 KB golden fixture: a corrupted header that
/// declares a plane bigger than the file must trip the budget (or the
/// bounds check), never an allocation.
fn limits() -> IngestLimits {
    IngestLimits {
        max_layers: 64,
        max_dims: 8,
        max_params: 1 << 20,
        max_file_bytes: 1 << 20,
        max_layer_bytes: 1 << 20,
    }
}

fn cand() -> Candidate {
    Candidate {
        method: Method::DcV2,
        s: 64.0,
        delta: 0.01,
        lambda: 1.0,
        clusters: 0,
    }
}

fn cfg(policy: NonFinitePolicy) -> SearchConfig {
    SearchConfig {
        container: ContainerPolicy::v3(512, 1),
        threads: 1,
        nonfinite: policy,
        ..SearchConfig::default()
    }
}

/// One contained parse attempt: must return (never unwind) and stay under
/// the allocation cap.  Returns the parsed network when the mutation was
/// indistinguishable from a valid file.
fn attempt_parse(raw: &[u8], label: &str) -> Option<Network> {
    let before = ALLOC_BYTES.load(Ordering::Relaxed);
    let r = catch_unwind(AssertUnwindSafe(|| parse_nwf(raw, limits())));
    let spent = ALLOC_BYTES.load(Ordering::Relaxed).wrapping_sub(before);
    assert!(r.is_ok(), "panic escaped parse_nwf: {label}");
    assert!(
        spent < ALLOC_CAP_BYTES,
        "{label}: parse allocated {spent} bytes (cap {ALLOC_CAP_BYTES})"
    );
    r.ok().and_then(|inner| inner.ok())
}

/// One contained encode attempt under `policy`.
fn attempt_compress(
    net: &Network,
    c: &Candidate,
    policy: NonFinitePolicy,
    label: &str,
) -> Result<CompressedNetwork, Error> {
    let before = ALLOC_BYTES.load(Ordering::Relaxed);
    let r = catch_unwind(AssertUnwindSafe(|| {
        compress_dc_policy(net, c, &cfg(policy)).map(|(comp, _)| comp)
    }));
    let spent = ALLOC_BYTES.load(Ordering::Relaxed).wrapping_sub(before);
    assert!(r.is_ok(), "panic escaped the encode path: {label}");
    assert!(
        spent < ALLOC_CAP_BYTES,
        "{label}: encode allocated {spent} bytes (cap {ALLOC_CAP_BYTES})"
    );
    match r {
        Ok(inner) => inner,
        Err(_) => unreachable!("asserted above"),
    }
}

/// The bit-exact half of the contract: the emitted container is
/// byte-stable under reserialize, and the fused single-pass decode agrees
/// with the two-pass reconstruction bit-for-bit.
fn assert_roundtrip(comp: &CompressedNetwork, label: &str) {
    let policy = ContainerPolicy::v3(512, 1);
    let bytes = comp.to_bytes_with(policy);
    let back = CompressedNetwork::from_bytes(&bytes)
        .unwrap_or_else(|e| panic!("{label}: emitted container failed to parse: {e}"));
    assert_eq!(
        bytes,
        back.to_bytes_with(policy),
        "{label}: container not byte-stable"
    );
    let mut arena = DecodeArena::new();
    let fused = decode_network_into(&bytes, 1, &mut arena)
        .unwrap_or_else(|e| panic!("{label}: fused decode refused own container: {e}"));
    let two = back.reconstruct_named();
    assert_eq!(fused.layers.len(), two.layers.len(), "{label}");
    for (a, b) in fused.layers.iter().zip(&two.layers) {
        assert_eq!(a.weights.len(), b.weights.len(), "{label}: {}", a.name);
        assert!(
            a.weights.iter().zip(&b.weights).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{label}: fused/two-pass reconstruction diverged on {}",
            a.name
        );
    }
}

/// Mirror of the policy layer's dirtiness predicate (the crate-internal
/// one is deliberately not public).
fn net_is_dirty(net: &Network) -> bool {
    let bad_imp = |v: &Vec<f32>| v.iter().any(|x| !x.is_finite() || *x < 0.0);
    net.layers.iter().any(|l| {
        l.weights.iter().any(|w| !w.is_finite())
            || l.fisher.as_ref().is_some_and(bad_imp)
            || l.hessian.as_ref().is_some_and(bad_imp)
            || l.bias.as_ref().is_some_and(|b| b.iter().any(|x| !x.is_finite()))
    })
}

#[test]
fn pristine_golden_nwf_parses_with_pinned_census() {
    let raw = fixture("golden.nwf");
    let net = parse_nwf(&raw, IngestLimits::default()).expect("pristine golden.nwf");
    assert_eq!(net.layers.len(), 3);
    assert_eq!(net.param_count(), 72 + 240);
    let conv1 = &net.layers[0];
    assert_eq!(conv1.name, "conv1");
    let c = conv1.weight_census();
    assert_eq!(
        (c.nan, c.pos_inf, c.neg_inf, c.subnormal, c.neg_zero),
        (1, 1, 1, 1, 1),
        "gen_golden.py plants exactly one of each special"
    );
    let fc1 = &net.layers[1];
    assert_eq!(fc1.weight_census().non_finite(), 0, "fc1 is clean");
    assert!(fc1.hessian.is_some() && fc1.fisher.is_none() && fc1.bias.is_none());
    let tiny = &net.layers[2];
    assert_eq!((tiny.rows, tiny.cols, tiny.weights.len()), (0, 5, 0));
}

#[test]
fn golden_nwf_policy_matrix_rejects_or_roundtrips() {
    let raw = fixture("golden.nwf");
    let net = parse_nwf(&raw, IngestLimits::default()).expect("pristine golden.nwf");
    // Reject: typed error naming the offending layer, input untouched.
    match compress_dc_policy(&net, &cand(), &cfg(NonFinitePolicy::Reject)) {
        Err(Error::NonFinite(m)) => assert!(m.contains("conv1"), "message names the layer: {m}"),
        other => panic!("Reject on a dirty checkpoint must fail NonFinite, got {other:?}"),
    }
    // Sanitize / Clamp: exact per-layer rewrite counts, then a bit-exact
    // container round-trip.  conv1: 3 non-finite weights (NaN, +Inf,
    // -Inf — the subnormal, -0.0 and f32::MAX stay untouched), 2 invalid
    // fisher entries (NaN + negative), 1 non-finite bias value.
    for policy in [NonFinitePolicy::Sanitize, NonFinitePolicy::Clamp] {
        let (comp, report) = compress_dc_policy(&net, &cand(), &cfg(policy))
            .unwrap_or_else(|e| panic!("{policy:?} must compress the golden fixture: {e}"));
        assert_eq!(report.layers.len(), 1, "only conv1 is dirty");
        let l = &report.layers[0];
        assert_eq!(
            (l.name.as_str(), l.weights_fixed, l.importance_fixed, l.bias_fixed),
            ("conv1", 3, 2, 1),
            "{policy:?}"
        );
        assert_roundtrip(&comp, &format!("golden.nwf under {policy:?}"));
    }
    // The original network is never mutated by any policy pass.
    assert_eq!(net.layers[0].weight_census().non_finite(), 3);
}

#[test]
fn exhaustive_nwf_single_byte_corruption_sweep() {
    let raw = fixture("golden.nwf");
    for i in (0..raw.len()).step_by(sweep_stride()) {
        // whole-byte flip, stale CRC: the gate's territory
        let mut m = raw.clone();
        m[i] ^= 0xFF;
        attempt_parse(&m, &format!("golden.nwf byte {i}"));
        // restamped: the mutation penetrates to header/budget validation;
        // anything the parser accepts must still encode cleanly
        restamp(&mut m);
        if let Some(net) = attempt_parse(&m, &format!("golden.nwf byte {i} restamped")) {
            let label = format!("golden.nwf byte {i} restamped, accepted");
            if let Ok(comp) =
                attempt_compress(&net, &cand(), NonFinitePolicy::Sanitize, &label)
            {
                assert_roundtrip(&comp, &label);
            } else {
                panic!("{label}: Sanitize must encode any parse-accepted network");
            }
        }
        // single-bit flip, restamped: the subtlest corruption class
        let mut b = raw.clone();
        flip_bit(&mut b, i, (i % 8) as u32);
        restamp(&mut b);
        attempt_parse(&b, &format!("golden.nwf bit {i}.{}", i % 8));
    }
}

#[test]
fn seeded_adversarial_networks_fail_typed_or_roundtrip_bit_exact() {
    let iters: usize = std::env::var("DCB_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if cfg!(debug_assertions) { 128 } else { 1024 });
    let mut gen = NetGen::new(0xE2C0_DE);
    let candidates = [
        cand(),
        Candidate {
            method: Method::DcV2,
            s: 0.0,
            delta: 0.25,
            lambda: 0.01,
            clusters: 0,
        },
        Candidate {
            method: Method::DcV1,
            s: 64.0,
            delta: 0.0,
            lambda: 0.5,
            clusters: 0,
        },
    ];
    let policies = [
        NonFinitePolicy::Reject,
        NonFinitePolicy::Sanitize,
        NonFinitePolicy::Clamp,
    ];
    for it in 0..iters {
        let net = gen.adversarial();
        let dirty = net_is_dirty(&net);
        let c = &candidates[it % candidates.len()];
        let policy = policies[it % policies.len()];
        let label = format!("iter {it} ({:?}, {policy:?}, dirty={dirty})", c.method);
        match attempt_compress(&net, c, policy, &label) {
            Ok(comp) => {
                assert!(
                    policy != NonFinitePolicy::Reject || !dirty,
                    "{label}: Reject let a dirty network through"
                );
                assert_roundtrip(&comp, &label);
            }
            Err(Error::NonFinite(_)) => {
                assert!(
                    policy == NonFinitePolicy::Reject && dirty,
                    "{label}: spurious NonFinite error"
                );
            }
            Err(e) => panic!("{label}: unexpected typed error {e}"),
        }
    }
}
