#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Property tests over the codec stack: roundtrip identity, size
//! consistency, entropy bounds — the invariants every lossless coder must
//! hold for arbitrary quantized planes.

use deepcabac::cabac::{self, CodingConfig};
use deepcabac::codecs::{csr::Csr, entropy, external, golomb, huffman};
use deepcabac::testutil::{check_slice, gen, Config};

fn cfg() -> Config {
    Config {
        cases: 120,
        seed: 0xC0DEC,
    }
}

#[test]
fn prop_cabac_roundtrip_sparse() {
    check_slice(cfg(), gen::sparse_symbols, |s| {
        let coding = CodingConfig::default();
        let bytes = cabac::encode_layer(s, coding);
        cabac::decode_layer(&bytes, s.len(), coding)
            .map(|d| d == s)
            .unwrap_or(false)
    });
}

#[test]
fn prop_cabac_roundtrip_wild() {
    check_slice(cfg(), gen::wild_symbols, |s| {
        let coding = CodingConfig::default();
        let bytes = cabac::encode_layer(s, coding);
        cabac::decode_layer(&bytes, s.len(), coding)
            .map(|d| d == s)
            .unwrap_or(false)
    });
}

#[test]
fn prop_cabac_roundtrip_small_configs() {
    check_slice(
        Config {
            cases: 60,
            seed: 0xA1,
        },
        gen::sparse_symbols,
        |s| {
            for n in [1u32, 2, 5] {
                let coding = CodingConfig {
                    max_abs_gr: n,
                    eg_contexts: n,
                };
                let bytes = cabac::encode_layer(s, coding);
                match cabac::decode_layer(&bytes, s.len(), coding) {
                    Ok(d) if d == s => {}
                    _ => return false,
                }
            }
            true
        },
    );
}

#[test]
fn prop_huffman_two_part_roundtrip() {
    check_slice(cfg(), gen::sparse_symbols, |s| {
        if s.is_empty() {
            return true;
        }
        huffman::encode_two_part(s)
            .and_then(|(_, raw)| huffman::decode_two_part(&raw))
            .map(|d| d == s)
            .unwrap_or(false)
    });
}

#[test]
fn prop_huffman_within_entropy_plus_one() {
    check_slice(cfg(), gen::sparse_symbols, |s| {
        if s.len() < 100 {
            return true; // bound is per-symbol, tables need some mass
        }
        let h = entropy::entropy_bits_per_symbol(s);
        let code = huffman::HuffmanCode::build(s);
        let avg = code.avg_bits(s);
        avg >= h - 1e-9 && avg < h + 1.0
    });
}

#[test]
fn prop_csr_roundtrip() {
    check_slice(cfg(), gen::sparse_symbols, |s| {
        // shape the plane into a pseudo-matrix
        let cols = (s.len() as f64).sqrt().ceil() as usize;
        if cols == 0 {
            return true;
        }
        let rows = s.len().div_ceil(cols);
        let mut dense = s.to_vec();
        dense.resize(rows * cols, 0);
        let csr = Csr::from_dense(&dense, rows, cols);
        if csr.to_dense() != dense {
            return false;
        }
        csr.encode()
            .and_then(|raw| Csr::decode(&raw))
            .map(|back| back.to_dense() == dense)
            .unwrap_or(false)
    });
}

#[test]
fn prop_golomb_roundtrip_all_orders() {
    check_slice(cfg(), gen::wild_symbols, |s| {
        (0..4).all(|k| {
            let raw = golomb::encode_stream(s, k);
            golomb::decode_stream(&raw, s.len(), k)
                .map(|d| d == s)
                .unwrap_or(false)
        })
    });
}

#[test]
fn prop_external_coders_roundtrip() {
    check_slice(
        Config {
            cases: 40,
            seed: 0xB2,
        },
        gen::sparse_symbols,
        |s| {
            let (p, packed) = external::pack_symbols(s);
            if external::unpack_symbols(p, &packed) != s {
                return false;
            }
            let bz = external::bzip2_compress(&packed).unwrap();
            if external::bzip2_decompress(&bz).unwrap() != packed {
                return false;
            }
            let zs = external::zstd_compress(&packed).unwrap();
            external::zstd_decompress(&zs, packed.len().max(1)).unwrap() == packed
        },
    );
}

/// Mixed context/bypass bin sequences at the arithmetic-coder level: the
/// batched bypass fast path must interleave with adaptive bins and the
/// single-bin bypass without corrupting either, including the 0-length
/// batch.  (The layer-level props cover the binarizer; this pins the raw
/// coder contract the binarizer relies on.)
#[test]
fn prop_arith_mixed_context_bypass_roundtrip() {
    #[derive(Clone, Copy)]
    enum Op {
        Ctx(usize, bool),
        Bypass(bool),
        Batch(u64, u32),
    }
    let mut rng = deepcabac::util::Pcg64::new(0xF00D);
    for trial in 0..40 {
        let n_ops = rng.below(3_000) as usize; // includes empty plans
        let plan: Vec<Op> = (0..n_ops)
            .map(|_| match rng.below(3) {
                0 => Op::Ctx(rng.below(4) as usize, rng.next_f64() < 0.3),
                1 => Op::Bypass(rng.next_f64() < 0.5),
                _ => {
                    let n = rng.below(65) as u32; // 0..=64, 0 = no-op batch
                    let v = if n == 0 {
                        0
                    } else if n == 64 {
                        rng.next_u64()
                    } else {
                        rng.next_u64() & ((1u64 << n) - 1)
                    };
                    Op::Batch(v, n)
                }
            })
            .collect();
        let mut ctxs = vec![deepcabac::cabac::Context::default(); 4];
        let mut e = deepcabac::cabac::Encoder::new();
        for &op in &plan {
            match op {
                Op::Ctx(c, b) => e.encode(&mut ctxs[c], b),
                Op::Bypass(b) => e.encode_bypass(b),
                Op::Batch(v, n) => e.encode_bypass_bits(v, n),
            }
        }
        let bytes = e.finish();
        let mut dctxs = vec![deepcabac::cabac::Context::default(); 4];
        let mut d = deepcabac::cabac::Decoder::new(&bytes);
        for (i, &op) in plan.iter().enumerate() {
            match op {
                Op::Ctx(c, b) => assert_eq!(d.decode(&mut dctxs[c]), b, "t{trial} op{i}"),
                Op::Bypass(b) => assert_eq!(d.decode_bypass(), b, "t{trial} op{i}"),
                Op::Batch(v, n) => {
                    assert_eq!(d.decode_bypass_bits(n), v, "t{trial} op{i} n={n}")
                }
            }
        }
        assert_eq!(ctxs, dctxs, "t{trial}");
    }
}

/// All-bypass streams (no context bin ever coded) must roundtrip — the
/// degenerate plan the renormalization edge cases hide in.
#[test]
fn prop_arith_all_bypass_stream_roundtrips() {
    let mut rng = deepcabac::util::Pcg64::new(0xF00E);
    for _ in 0..20 {
        let widths: Vec<u32> = (0..rng.below(2_000)).map(|_| rng.below(33) as u32).collect();
        let vals: Vec<u64> = widths
            .iter()
            .map(|&n| {
                if n == 0 {
                    0
                } else {
                    rng.next_u64() & ((1u64 << n) - 1)
                }
            })
            .collect();
        let mut e = deepcabac::cabac::Encoder::new();
        for (&v, &n) in vals.iter().zip(&widths) {
            e.encode_bypass_bits(v, n);
        }
        let bytes = e.finish();
        let mut d = deepcabac::cabac::Decoder::new(&bytes);
        for (&v, &n) in vals.iter().zip(&widths) {
            assert_eq!(d.decode_bypass_bits(n), v);
        }
    }
}

/// Legacy (v1/v2 bins) and v3 layer coding must each roundtrip on the same
/// planes, produce distinct streams whenever a sign bin exists, and stay
/// within a few percent of each other in size.
#[test]
fn prop_legacy_and_v3_layers_roundtrip_on_same_planes() {
    let mut rng = deepcabac::util::Pcg64::new(0xF00F);
    let coding = CodingConfig::default();
    for trial in 0..25 {
        let n = rng.below(4_000) as usize;
        let values: Vec<i32> = (0..n)
            .map(|_| {
                let r = rng.next_f64();
                if r < 0.55 {
                    0
                } else if r < 0.9 {
                    rng.below(60) as i32 - 30
                } else {
                    rng.below(2_000_000) as i32 - 1_000_000
                }
            })
            .collect();
        let v3 = cabac::encode_layer(&values, coding);
        let legacy = cabac::encode_layer_legacy(&values, coding);
        assert_eq!(
            cabac::decode_layer(&v3, values.len(), coding).unwrap(),
            values,
            "t{trial} v3"
        );
        assert_eq!(
            cabac::decode_layer_legacy(&legacy, values.len(), coding).unwrap(),
            values,
            "t{trial} legacy"
        );
        if values.iter().any(|&v| v != 0) {
            let small = v3.len().min(legacy.len()) as f64;
            let big = v3.len().max(legacy.len()) as f64;
            assert!(
                big / small < 1.05 + 32.0 / small,
                "t{trial}: v3 {} B vs legacy {} B",
                v3.len(),
                legacy.len()
            );
        }
    }
}

#[test]
fn prop_cabac_never_catastrophically_expands() {
    // Even on adversarial (high-entropy) planes, the CABAC stream must stay
    // within a small constant factor of the raw 4-byte representation.
    check_slice(cfg(), gen::wild_symbols, |s| {
        let bytes = cabac::encode_layer(s, CodingConfig::default());
        bytes.len() <= s.len() * 6 + 64
    });
}

#[test]
fn prop_cabac_beats_huffman_family_on_sparse_planes() {
    // The Table III ordering, as a property over random sparse planes large
    // enough for adaptation to settle.
    check_slice(
        Config {
            cases: 30,
            seed: 0xD3,
        },
        |rng| {
            let n = 20_000 + rng.below(20_000) as usize;
            let zero_p = rng.uniform(0.6, 0.95);
            (0..n)
                .map(|_| {
                    if rng.next_f64() < zero_p {
                        0
                    } else {
                        let m = 1 + (rng.next_f64() * rng.next_f64() * 20.0) as i32;
                        if rng.next_f64() < 0.5 {
                            -m
                        } else {
                            m
                        }
                    }
                })
                .collect::<Vec<i32>>()
        },
        |s| {
            let coding = CodingConfig::default();
            let cabac_sz = cabac::encode_layer(s, coding).len();
            let (_, huff) = huffman::encode_two_part(s).unwrap();
            cabac_sz <= huff.len()
        },
    );
}

/// Shape a symbol plane into a one-layer [`CompressedNetwork`].
fn plane_network(s: &[i32]) -> deepcabac::model::CompressedNetwork {
    use deepcabac::model::{CompressedNetwork, Kind, QuantizedLayer};
    let cols = (s.len() as f64).sqrt().ceil().max(1.0) as usize;
    let rows = s.len().div_ceil(cols).max(1);
    let mut ints = s.to_vec();
    ints.resize(rows * cols, 0);
    CompressedNetwork {
        name: "prop".into(),
        cfg: CodingConfig::default(),
        layers: vec![QuantizedLayer {
            name: "l".into(),
            kind: Kind::Dense,
            shape: vec![cols, rows],
            rows,
            cols,
            ints,
            delta: 0.0123,
            bias: Some(vec![0.5; rows]),
        }],
    }
}

/// Recompute the container CRC after tampering with the body, so the
/// tamper reaches the header/slice validation instead of the CRC check.
fn refix_crc(bytes: &mut [u8]) {
    let n = bytes.len();
    let crc = deepcabac::util::crc32(&bytes[4..n - 4]);
    bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
}

#[test]
fn prop_dcb_container_roundtrip() {
    use deepcabac::model::{CompressedNetwork, Kind, QuantizedLayer};
    check_slice(
        Config {
            cases: 60,
            seed: 0xE4,
        },
        gen::sparse_symbols,
        |s| {
            let cols = (s.len() as f64).sqrt().ceil().max(1.0) as usize;
            let rows = s.len().div_ceil(cols).max(1);
            let mut ints = s.to_vec();
            ints.resize(rows * cols, 0);
            let net = CompressedNetwork {
                name: "prop".into(),
                cfg: CodingConfig::default(),
                layers: vec![QuantizedLayer {
                    name: "l".into(),
                    kind: Kind::Dense,
                    shape: vec![cols, rows],
                    rows,
                    cols,
                    ints: ints.clone(),
                    delta: 0.0123,
                    bias: Some(vec![0.5; rows]),
                }],
            };
            CompressedNetwork::from_bytes(&net.to_bytes())
                .map(|b| b.layers[0].ints == ints)
                .unwrap_or(false)
        },
    );
}

#[test]
fn prop_dcb2_container_roundtrip() {
    use deepcabac::model::{CompressedNetwork, ContainerPolicy};
    check_slice(
        Config {
            cases: 60,
            seed: 0xE5,
        },
        gen::sparse_symbols,
        |s| {
            let net = plane_network(s);
            // Exercise slice boundaries around the plane size.
            for slice_len in [1usize, 97, s.len().max(1)] {
                for threads in [1usize, 4] {
                    let bytes = net.to_bytes_with(ContainerPolicy::v2(slice_len, threads));
                    let ok = CompressedNetwork::from_bytes_with(&bytes, threads)
                        .map(|b| b.layers == net.layers)
                        .unwrap_or(false);
                    if !ok {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_dcb_v1_streams_decode_byte_exact_under_dispatcher() {
    // v1 streams must keep decoding after the v2 dispatch was added, and
    // re-encoding the decoded network as v1 must reproduce the bytes.
    use deepcabac::model::CompressedNetwork;
    check_slice(
        Config {
            cases: 40,
            seed: 0xE6,
        },
        gen::sparse_symbols,
        |s| {
            let net = plane_network(s);
            let v1 = net.to_bytes();
            let Ok(back) = CompressedNetwork::from_bytes(&v1) else {
                return false;
            };
            back.layers == net.layers && back.to_bytes() == v1
        },
    );
}

#[test]
fn dcb_v1_and_v2_decode_identically_across_thread_counts() {
    use deepcabac::model::{CompressedNetwork, ContainerPolicy};
    let mut rng = deepcabac::util::Pcg64::new(0xE7);
    let s: Vec<i32> = (0..40_000)
        .map(|_| {
            if rng.next_f64() < 0.85 {
                0
            } else {
                rng.below(25) as i32 - 12
            }
        })
        .collect();
    let net = plane_network(&s);
    let v1 = net.to_bytes();
    let v2 = net.to_bytes_with(ContainerPolicy::v2(4096, 4));
    let d1 = CompressedNetwork::from_bytes_with(&v1, 1).unwrap();
    for threads in [1usize, 2, 8] {
        let dv1 = CompressedNetwork::from_bytes_with(&v1, threads).unwrap();
        let dv2 = CompressedNetwork::from_bytes_with(&v2, threads).unwrap();
        assert_eq!(dv1.layers, d1.layers, "v1 threads={threads}");
        assert_eq!(dv2.layers, d1.layers, "v2 threads={threads}");
    }
}

#[test]
fn prop_dcb3_container_roundtrip() {
    use deepcabac::model::{CompressedNetwork, ContainerPolicy};
    check_slice(
        Config {
            cases: 60,
            seed: 0xE5D,
        },
        gen::sparse_symbols,
        |s| {
            let net = plane_network(s);
            // Exercise slice boundaries around the plane size.
            for slice_len in [1usize, 97, s.len().max(1)] {
                for threads in [1usize, 4] {
                    let bytes = net.to_bytes_with(ContainerPolicy::v3(slice_len, threads));
                    let ok = CompressedNetwork::from_bytes_with(&bytes, threads)
                        .map(|b| b.layers == net.layers)
                        .unwrap_or(false);
                    if !ok {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn dcb3_rejects_truncation_and_flips() {
    use deepcabac::model::{CompressedNetwork, ContainerPolicy};
    let mut rng = deepcabac::util::Pcg64::new(0xEC);
    let s: Vec<i32> = (0..5000).map(|_| rng.below(7) as i32 - 3).collect();
    let clean = plane_network(&s).to_bytes_with(ContainerPolicy::v3(512, 2));
    for cut in [0, 3, 8, clean.len() / 4, clean.len() / 2, clean.len() - 5] {
        assert!(
            CompressedNetwork::from_bytes(&clean[..cut]).is_err(),
            "cut={cut}"
        );
    }
    for pos in [5, clean.len() / 3, clean.len() - 1] {
        let mut bytes = clean.clone();
        bytes[pos] ^= 0x40;
        assert!(CompressedNetwork::from_bytes(&bytes).is_err(), "pos={pos}");
    }
}

#[test]
fn dcb2_rejects_truncation() {
    use deepcabac::model::{CompressedNetwork, ContainerPolicy};
    let mut rng = deepcabac::util::Pcg64::new(0xE8);
    let s: Vec<i32> = (0..5000).map(|_| rng.below(7) as i32 - 3).collect();
    let bytes = plane_network(&s).to_bytes_with(ContainerPolicy::v2(512, 2));
    for cut in [0, 3, 8, bytes.len() / 4, bytes.len() / 2, bytes.len() - 5] {
        assert!(
            CompressedNetwork::from_bytes(&bytes[..cut]).is_err(),
            "cut={cut}"
        );
    }
}

#[test]
fn dcb2_rejects_crc_flips() {
    use deepcabac::model::{CompressedNetwork, ContainerPolicy};
    let mut rng = deepcabac::util::Pcg64::new(0xE9);
    let s: Vec<i32> = (0..3000).map(|_| rng.below(11) as i32 - 5).collect();
    let clean = plane_network(&s).to_bytes_with(ContainerPolicy::v2(256, 2));
    assert!(CompressedNetwork::from_bytes(&clean).is_ok());
    for pos in [5, clean.len() / 3, clean.len() / 2, clean.len() - 1] {
        let mut bytes = clean.clone();
        bytes[pos] ^= 0x10;
        assert!(CompressedNetwork::from_bytes(&bytes).is_err(), "pos={pos}");
    }
}

#[test]
fn dcb2_rejects_implausible_slice_headers() {
    use deepcabac::model::{CompressedNetwork, ContainerPolicy};
    let mut rng = deepcabac::util::Pcg64::new(0xEA);
    let s: Vec<i32> = (0..4000).map(|_| rng.below(9) as i32 - 4).collect();
    let net = plane_network(&s);
    let l = &net.layers[0];
    let clean = net.to_bytes_with(ContainerPolicy::v2(500, 1));
    // Offset of the first layer's payload (which starts with u32
    // slice_len), per the wire layout in model/bitstream.rs:
    let payload_off = 4 + 1                      // magic | version
        + 2 + net.name.len()                     // model name
        + 4 + 4                                  // coding config
        + 4                                      // n_layers
        + 2 + l.name.len()                       // layer name
        + 1 + 1 + 4 * l.shape.len()              // kind | n_dims | dims
        + 4 + 4 + 4 + 1                          // rows | cols | delta | has_bias
        + 4 + 4 * l.bias.as_ref().unwrap().len() // blen | bias
        + 4; //                                     payload_len
    // sanity: the clean stream really has slice_len == 500 there
    assert_eq!(
        u32::from_le_bytes(clean[payload_off..payload_off + 4].try_into().unwrap()),
        500
    );
    // slice_len = 0 -> header implausible
    let mut zero_len = clean.clone();
    zero_len[payload_off..payload_off + 4].copy_from_slice(&0u32.to_le_bytes());
    refix_crc(&mut zero_len);
    assert!(CompressedNetwork::from_bytes(&zero_len).is_err());
    // slice_len inconsistent with the slice count -> rejected
    let mut wrong_len = clean.clone();
    wrong_len[payload_off..payload_off + 4].copy_from_slice(&50u32.to_le_bytes());
    refix_crc(&mut wrong_len);
    assert!(CompressedNetwork::from_bytes(&wrong_len).is_err());
    // absurd slice count -> rejected
    let mut wrong_n = clean;
    wrong_n[payload_off + 4..payload_off + 8]
        .copy_from_slice(&0xFFFF_FFu32.to_le_bytes());
    refix_crc(&mut wrong_n);
    assert!(CompressedNetwork::from_bytes(&wrong_n).is_err());
}

#[test]
fn dcb_probe_reports_container_structure() {
    use deepcabac::model::{probe, ContainerPolicy, VERSION_V1, VERSION_V2};
    let mut rng = deepcabac::util::Pcg64::new(0xEB);
    let s: Vec<i32> = (0..2500).map(|_| rng.below(5) as i32 - 2).collect();
    let net = plane_network(&s);
    let p1 = probe(&net.to_bytes()).unwrap();
    assert_eq!(p1.version, VERSION_V1);
    assert_eq!(p1.total_slices(), 1);
    let p2 = probe(&net.to_bytes_with(ContainerPolicy::v2(300, 2))).unwrap();
    assert_eq!(p2.version, VERSION_V2);
    assert_eq!(p2.layers[0].n_slices, net.layers[0].ints.len().div_ceil(300));
    assert_eq!(p2.param_count(), net.param_count());
}

#[test]
fn prop_sliced_rdoq_thread_invariant_byte_identical_streams() {
    // For any weight plane and slice length: slice-aligned RDOQ assignments
    // must be invariant to thread count, and encoding those assignments
    // serially vs in parallel must yield byte-identical sliced streams.
    use deepcabac::quant::rd::{
        rd_quantize_layer_sliced, rd_quantize_layer_sliced_parallel, required_half, RdParams,
    };
    check_slice(
        Config {
            cases: 24,
            seed: 0x5D00,
        },
        gen::weights,
        |w| {
            let coding = CodingConfig::default();
            let delta = 0.01f32;
            let p = RdParams::new(delta, 2.0 * delta * delta, required_half(w, delta, 256));
            for slice_len in [5usize, 257, 4096] {
                let (serial, serial_bits) = rd_quantize_layer_sliced(w, &[], &p, slice_len);
                for threads in [2usize, 4] {
                    let (par, par_bits) =
                        rd_quantize_layer_sliced_parallel(w, &[], &p, slice_len, threads);
                    if par != serial || par_bits != serial_bits {
                        return false;
                    }
                }
                let a = cabac::encode_layer_sliced(&serial, coding, slice_len);
                let b = cabac::encode_layer_sliced_parallel(&serial, coding, slice_len, 3);
                if a != b {
                    return false;
                }
            }
            true
        },
    );
}
