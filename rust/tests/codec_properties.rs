//! Property tests over the codec stack: roundtrip identity, size
//! consistency, entropy bounds — the invariants every lossless coder must
//! hold for arbitrary quantized planes.

use deepcabac::cabac::{self, CodingConfig};
use deepcabac::codecs::{csr::Csr, entropy, external, golomb, huffman};
use deepcabac::testutil::{check_slice, gen, Config};

fn cfg() -> Config {
    Config {
        cases: 120,
        seed: 0xC0DEC,
    }
}

#[test]
fn prop_cabac_roundtrip_sparse() {
    check_slice(cfg(), gen::sparse_symbols, |s| {
        let coding = CodingConfig::default();
        let bytes = cabac::encode_layer(s, coding);
        cabac::decode_layer(&bytes, s.len(), coding)
            .map(|d| d == s)
            .unwrap_or(false)
    });
}

#[test]
fn prop_cabac_roundtrip_wild() {
    check_slice(cfg(), gen::wild_symbols, |s| {
        let coding = CodingConfig::default();
        let bytes = cabac::encode_layer(s, coding);
        cabac::decode_layer(&bytes, s.len(), coding)
            .map(|d| d == s)
            .unwrap_or(false)
    });
}

#[test]
fn prop_cabac_roundtrip_small_configs() {
    check_slice(
        Config {
            cases: 60,
            seed: 0xA1,
        },
        gen::sparse_symbols,
        |s| {
            for n in [1u32, 2, 5] {
                let coding = CodingConfig {
                    max_abs_gr: n,
                    eg_contexts: n,
                };
                let bytes = cabac::encode_layer(s, coding);
                match cabac::decode_layer(&bytes, s.len(), coding) {
                    Ok(d) if d == s => {}
                    _ => return false,
                }
            }
            true
        },
    );
}

#[test]
fn prop_huffman_two_part_roundtrip() {
    check_slice(cfg(), gen::sparse_symbols, |s| {
        if s.is_empty() {
            return true;
        }
        huffman::encode_two_part(s)
            .and_then(|(_, raw)| huffman::decode_two_part(&raw))
            .map(|d| d == s)
            .unwrap_or(false)
    });
}

#[test]
fn prop_huffman_within_entropy_plus_one() {
    check_slice(cfg(), gen::sparse_symbols, |s| {
        if s.len() < 100 {
            return true; // bound is per-symbol, tables need some mass
        }
        let h = entropy::entropy_bits_per_symbol(s);
        let code = huffman::HuffmanCode::build(s);
        let avg = code.avg_bits(s);
        avg >= h - 1e-9 && avg < h + 1.0
    });
}

#[test]
fn prop_csr_roundtrip() {
    check_slice(cfg(), gen::sparse_symbols, |s| {
        // shape the plane into a pseudo-matrix
        let cols = (s.len() as f64).sqrt().ceil() as usize;
        if cols == 0 {
            return true;
        }
        let rows = s.len().div_ceil(cols);
        let mut dense = s.to_vec();
        dense.resize(rows * cols, 0);
        let csr = Csr::from_dense(&dense, rows, cols);
        if csr.to_dense() != dense {
            return false;
        }
        csr.encode()
            .and_then(|raw| Csr::decode(&raw))
            .map(|back| back.to_dense() == dense)
            .unwrap_or(false)
    });
}

#[test]
fn prop_golomb_roundtrip_all_orders() {
    check_slice(cfg(), gen::wild_symbols, |s| {
        (0..4).all(|k| {
            let raw = golomb::encode_stream(s, k);
            golomb::decode_stream(&raw, s.len(), k)
                .map(|d| d == s)
                .unwrap_or(false)
        })
    });
}

#[test]
fn prop_external_coders_roundtrip() {
    check_slice(
        Config {
            cases: 40,
            seed: 0xB2,
        },
        gen::sparse_symbols,
        |s| {
            let (p, packed) = external::pack_symbols(s);
            if external::unpack_symbols(p, &packed) != s {
                return false;
            }
            let bz = external::bzip2_compress(&packed).unwrap();
            if external::bzip2_decompress(&bz).unwrap() != packed {
                return false;
            }
            let zs = external::zstd_compress(&packed).unwrap();
            external::zstd_decompress(&zs, packed.len().max(1)).unwrap() == packed
        },
    );
}

#[test]
fn prop_cabac_never_catastrophically_expands() {
    // Even on adversarial (high-entropy) planes, the CABAC stream must stay
    // within a small constant factor of the raw 4-byte representation.
    check_slice(cfg(), gen::wild_symbols, |s| {
        let bytes = cabac::encode_layer(s, CodingConfig::default());
        bytes.len() <= s.len() * 6 + 64
    });
}

#[test]
fn prop_cabac_beats_huffman_family_on_sparse_planes() {
    // The Table III ordering, as a property over random sparse planes large
    // enough for adaptation to settle.
    check_slice(
        Config {
            cases: 30,
            seed: 0xD3,
        },
        |rng| {
            let n = 20_000 + rng.below(20_000) as usize;
            let zero_p = rng.uniform(0.6, 0.95);
            (0..n)
                .map(|_| {
                    if rng.next_f64() < zero_p {
                        0
                    } else {
                        let m = 1 + (rng.next_f64() * rng.next_f64() * 20.0) as i32;
                        if rng.next_f64() < 0.5 {
                            -m
                        } else {
                            m
                        }
                    }
                })
                .collect::<Vec<i32>>()
        },
        |s| {
            let coding = CodingConfig::default();
            let cabac_sz = cabac::encode_layer(s, coding).len();
            let (_, huff) = huffman::encode_two_part(s).unwrap();
            cabac_sz <= huff.len()
        },
    );
}

#[test]
fn prop_dcb_container_roundtrip() {
    use deepcabac::model::{CompressedNetwork, Kind, QuantizedLayer};
    check_slice(
        Config {
            cases: 60,
            seed: 0xE4,
        },
        gen::sparse_symbols,
        |s| {
            let cols = (s.len() as f64).sqrt().ceil().max(1.0) as usize;
            let rows = s.len().div_ceil(cols).max(1);
            let mut ints = s.to_vec();
            ints.resize(rows * cols, 0);
            let net = CompressedNetwork {
                name: "prop".into(),
                cfg: CodingConfig::default(),
                layers: vec![QuantizedLayer {
                    name: "l".into(),
                    kind: Kind::Dense,
                    shape: vec![cols, rows],
                    rows,
                    cols,
                    ints: ints.clone(),
                    delta: 0.0123,
                    bias: Some(vec![0.5; rows]),
                }],
            };
            CompressedNetwork::from_bytes(&net.to_bytes())
                .map(|b| b.layers[0].ints == ints)
                .unwrap_or(false)
        },
    );
}
