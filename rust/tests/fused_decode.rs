#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Fused decode→inference equivalence suite.
//!
//! Pins the zero-allocation decode path against the classic two-pass one:
//! `decode_layer_dequant_into` must be bit-exactly `decode_layer_into` +
//! `dequantize()`, serial must equal pooled-parallel, the golden-vector
//! containers must produce identical float planes through both paths (no
//! wire change), and `DecodeArena` reuse across different networks must
//! never leak stale plane contents.

use std::path::PathBuf;

use deepcabac::cabac::{
    decode_layer_dequant_into, decode_layer_dequant_sliced_into, decode_layer_into,
    decode_layer_into_legacy, decode_layer_sliced, encode_layer, encode_layer_legacy,
    encode_layer_sliced, CodingConfig, WeightContexts,
};
use deepcabac::model::{
    decode_network_into, decode_network_into_on, CompressedNetwork, ContainerPolicy, DecodeArena,
    Kind, QuantizedLayer,
};
use deepcabac::util::parallel::Pool;
use deepcabac::util::Pcg64;

fn sparse_ints(n: usize, rng: &mut Pcg64) -> Vec<i32> {
    (0..n)
        .map(|_| {
            if rng.next_f64() < 0.7 {
                0
            } else {
                let m = 1 + (rng.next_f64() * rng.next_f64() * 120.0) as i32;
                if rng.next_f64() < 0.5 {
                    -m
                } else {
                    m
                }
            }
        })
        .collect()
}

fn random_network(name: &str, layer_dims: &[(usize, usize)], rng: &mut Pcg64) -> CompressedNetwork {
    let layers = layer_dims
        .iter()
        .enumerate()
        .map(|(i, &(rows, cols))| QuantizedLayer {
            name: format!("l{i}"),
            kind: if i % 2 == 0 { Kind::Dense } else { Kind::Conv },
            shape: vec![cols, rows],
            rows,
            cols,
            ints: sparse_ints(rows * cols, rng),
            delta: 0.0078125 * (i + 1) as f32,
            bias: (i % 2 == 0).then(|| (0..rows).map(|r| r as f32 * 0.125).collect()),
        })
        .collect();
    CompressedNetwork {
        name: name.into(),
        cfg: CodingConfig::default(),
        layers,
    }
}

fn assert_fused_equals_two_pass(bytes: &[u8], arena: &mut DecodeArena, tag: &str) {
    let expected = CompressedNetwork::from_bytes(bytes).unwrap().reconstruct_named();
    for threads in [1usize, 4] {
        let got = decode_network_into(bytes, threads, arena).unwrap();
        assert_eq!(got.name, expected.name, "{tag}");
        assert_eq!(got.layers.len(), expected.layers.len(), "{tag}");
        for (a, b) in got.layers.iter().zip(&expected.layers) {
            assert_eq!(a.name, b.name, "{tag}");
            assert_eq!(a.weights, b.weights, "{tag} threads={threads} layer {}", a.name);
            assert_eq!(a.bias, b.bias, "{tag}");
            assert_eq!(a.shape, b.shape, "{tag}");
        }
    }
}

#[test]
fn layer_kernel_equals_decode_plus_dequantize_prop() {
    // Prop corpus over sparsities, magnitudes and both bin formats: the
    // fused plane kernel must reproduce decode_layer_into + `i * delta`
    // bit-exactly (f32 multiplication is deterministic, so equality is
    // exact, not approximate).
    let mut rng = Pcg64::new(0xF05E);
    let cfg = CodingConfig::default();
    let mut scratch = WeightContexts::new(cfg);
    for trial in 0..12 {
        let n = 1 + rng.below(6000) as usize;
        let values = sparse_ints(n, &mut rng);
        let delta = 0.001 + rng.next_f64() as f32 * 0.1;
        for legacy in [false, true] {
            let bytes = if legacy {
                encode_layer_legacy(&values, cfg)
            } else {
                encode_layer(&values, cfg)
            };
            let mut ints = vec![0i32; n];
            let mut floats = vec![f32::NAN; n];
            if legacy {
                decode_layer_into_legacy(&bytes, &mut scratch, &mut ints).unwrap();
                decode_layer_dequant_into::<true>(&bytes, &mut scratch, delta, &mut floats)
                    .unwrap();
            } else {
                decode_layer_into(&bytes, &mut scratch, &mut ints).unwrap();
                decode_layer_dequant_into::<false>(&bytes, &mut scratch, delta, &mut floats)
                    .unwrap();
            }
            assert_eq!(ints, values, "trial {trial} legacy={legacy}");
            for (&i, &f) in ints.iter().zip(&floats) {
                assert_eq!(f, i as f32 * delta, "trial {trial} legacy={legacy}");
            }
        }
    }
}

#[test]
fn sliced_kernel_serial_equals_pooled_parallel() {
    let mut rng = Pcg64::new(0xF15E);
    let cfg = CodingConfig::default();
    let values = sparse_ints(40_000, &mut rng);
    let delta = 0.03125f32;
    for slice_len in [333usize, 4096] {
        let raw = encode_layer_sliced(&values, cfg, slice_len);
        let ints = decode_layer_sliced(&raw, values.len(), cfg, 1).unwrap();
        let mut serial = vec![f32::NAN; values.len()];
        decode_layer_dequant_sliced_into(&raw, cfg, delta, 1, &mut serial).unwrap();
        for threads in [2usize, 4, 8] {
            let mut par = vec![f32::NAN; values.len()];
            decode_layer_dequant_sliced_into(&raw, cfg, delta, threads, &mut par).unwrap();
            assert_eq!(par, serial, "slice_len={slice_len} threads={threads}");
        }
        for (&i, &f) in ints.iter().zip(&serial) {
            assert_eq!(f, i as f32 * delta);
        }
    }
}

#[test]
fn container_prop_corpus_fused_equals_two_pass() {
    // Random networks × container policies (all three versions): the fused
    // arena decode must equal the two-pass reference everywhere, reusing
    // one arena across the whole corpus (constant shape churn — the
    // stale-contents stress case).
    let mut rng = Pcg64::new(0xF25E);
    let mut arena = DecodeArena::new();
    let nets = [
        random_network("a", &[(30, 40), (17, 23)], &mut rng),
        random_network("b", &[(8, 8)], &mut rng),
        random_network("c", &[(30, 40), (17, 23), (5, 120)], &mut rng),
        random_network("empty", &[], &mut rng),
        random_network("zerolayer", &[(0, 7), (9, 11)], &mut rng),
    ];
    for net in &nets {
        for policy in [
            ContainerPolicy::v1(),
            ContainerPolicy::v2(100, 2),
            ContainerPolicy::v3(64, 3),
            ContainerPolicy::default(),
        ] {
            let bytes = net.to_bytes_with(policy);
            assert_fused_equals_two_pass(
                &bytes,
                &mut arena,
                &format!("net {} v{}", net.name, policy.version),
            );
        }
    }
}

#[test]
fn arena_reuse_across_networks_matches_fresh_arena() {
    // A warmed arena (same shapes, different payloads) and a cold-rebuilt
    // arena (different shapes) must both equal a fresh-arena decode: no
    // stale plane contents, no stale slice tables.
    let mut rng = Pcg64::new(0xF35E);
    let a = random_network("shape_x", &[(25, 31), (12, 12)], &mut rng);
    let mut b = random_network("shape_x", &[(25, 31), (12, 12)], &mut rng);
    b.layers[0].ints.iter_mut().for_each(|v| *v = 0); // mostly-empty twin
    let c = random_network("shape_y", &[(6, 9)], &mut rng);
    let mut shared = DecodeArena::new();
    for net in [&a, &b, &c, &a, &b] {
        let bytes = net.to_bytes_with(ContainerPolicy::v3(128, 2));
        let mut fresh = DecodeArena::new();
        let want = decode_network_into(&bytes, 2, &mut fresh).unwrap();
        let want_layers: Vec<Vec<f32>> = want.layers.iter().map(|l| l.weights.clone()).collect();
        let got = decode_network_into(&bytes, 2, &mut shared).unwrap();
        assert_eq!(got.layers.len(), want_layers.len());
        for (g, w) in got.layers.iter().zip(&want_layers) {
            assert_eq!(&g.weights, w, "net {}", net.name);
        }
    }
}

#[test]
fn injected_pool_decodes_identically_to_global() {
    let mut rng = Pcg64::new(0xF45E);
    let net = random_network("inj", &[(40, 50), (20, 20)], &mut rng);
    let bytes = net.to_bytes_with(ContainerPolicy::v3(256, 4));
    let mut a1 = DecodeArena::new();
    let mut a2 = DecodeArena::new();
    let pool = Pool::new();
    let via_global = decode_network_into(&bytes, 4, &mut a1).unwrap();
    let g: Vec<Vec<f32>> = via_global.layers.iter().map(|l| l.weights.clone()).collect();
    let via_injected = decode_network_into_on(&pool, &bytes, 4, &mut a2).unwrap();
    for (x, y) in via_injected.layers.iter().zip(&g) {
        assert_eq!(&x.weights, y);
    }
}

fn fixture(name: &str) -> Vec<u8> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/golden")
        .join(name);
    std::fs::read(&path).unwrap_or_else(|e| panic!("missing fixture {path:?}: {e}"))
}

#[test]
fn golden_vectors_decode_identically_through_fused_path() {
    // The acceptance pin: golden v1/v2/v3 streams — byte-identical on the
    // wire (no format change; golden_vectors.rs pins the bytes) — must
    // reconstruct the same float planes through the fused arena path as
    // through the two-pass path, serial and pooled, sharing ONE arena
    // across all three versions (same model identity → warm reuse).
    let mut arena = DecodeArena::new();
    for file in ["golden_v1.dcb", "golden_v2.dcb", "golden_v3.dcb"] {
        let raw = fixture(file);
        assert_fused_equals_two_pass(&raw, &mut arena, file);
    }
}
