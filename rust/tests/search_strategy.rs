#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Seeded end-to-end tests of the estimate-first grid search against the
//! exact-always reference, over a deterministic in-process accuracy oracle
//! (`EvalService::from_fn`) — no PJRT artifacts required.
//!
//! Pins the two assumptions the estimate-first tentpole rests on:
//!  1. the RDOQ rate estimate prices candidates well enough that the Pareto
//!     front, best candidate, and reported (real-byte) survivor sizes are
//!     identical to trial-encoding everything;
//!  2. CABAC is lossless, so accuracy evaluated on the quantizer's ints
//!     equals accuracy evaluated on the decoded stream — for every
//!     candidate, not just the survivors.

use deepcabac::coordinator::pipeline::{compress_dc, BACKEND_CABAC_ESTIMATED, EST_RATE_TOLERANCE};
use deepcabac::coordinator::{self, Candidate, Method, SearchConfig, SearchStrategy};
use deepcabac::model::{CompressedNetwork, ContainerPolicy, Kind, Layer, Network};
use deepcabac::runtime::EvalService;
use deepcabac::util::Pcg64;

fn synth_net() -> Network {
    let mut rng = Pcg64::new(0x5EED);
    let mk = |name: &str, n: usize, scale: f32, zero: f64, rng: &mut Pcg64| Layer {
        name: name.into(),
        kind: Kind::Dense,
        shape: vec![n, 1],
        rows: 1,
        cols: n,
        weights: rng.sparse_laplace_vec(n, scale, zero),
        // Fisher diagonal sized so DC-v1's eq. 12 lands on step-sizes in the
        // same regime as DC-v2's feasible Δ band (σ_min ≈ 4.5e-3).
        fisher: Some((0..n).map(|i| 1e4 * (1.0 + (i % 5) as f32)).collect()),
        hessian: None,
        bias: None,
    };
    Network {
        name: "strat".into(),
        layers: vec![
            mk("a", 2400, 0.05, 0.4, &mut rng),
            mk("b", 1200, 0.08, 0.3, &mut rng),
        ],
    }
}

/// Deterministic proxy oracle (`benchutil::closeness_oracle`): fraction of
/// weights reconstructed within 0.004 of the original, floor-quantized to
/// 1/64 steps — quantized like top-1 over a finite eval set, so accuracy
/// plateaus keep Pareto fronts realistically small.
fn oracle(net: &Network) -> EvalService {
    deepcabac::benchutil::closeness_oracle(net.clone(), 0.004, 64.0)
}

fn cfg(strategy: SearchStrategy) -> SearchConfig {
    SearchConfig {
        container: ContainerPolicy::v3(1024, 2),
        threads: 2,
        dc1_lambdas: 3,
        dc2_deltas: 10,
        dc2_keep: 2,
        dc2_lambdas: 5,
        strategy,
        ..SearchConfig::default()
    }
}

#[test]
fn estimate_first_matches_exact_always_front_best_and_reported_sizes() {
    let net = synth_net();
    let svc = oracle(&net);
    for method in [Method::DcV2, Method::DcV1] {
        let est =
            coordinator::search(&net, method, &cfg(SearchStrategy::EstimateFirst), &svc).unwrap();
        let exact =
            coordinator::search(&net, method, &cfg(SearchStrategy::ExactAlways), &svc).unwrap();
        assert_eq!(est.results.len(), exact.results.len(), "{method:?}");
        assert!(est.results.len() >= 6, "grid too small to mean anything");
        // Same grid, same accuracies (identical quantizations — phase A
        // evaluates the quantizer's ints, exact mode the decoded stream).
        for (e, x) in est.results.iter().zip(&exact.results) {
            assert_eq!(e.candidate, x.candidate);
            assert_eq!(e.accuracy, x.accuracy, "{:?}", e.candidate);
        }
        // Identical Pareto front and best candidate...
        let front_est = deepcabac::coordinator::pareto::pareto_front(&est.results);
        let front_exact = deepcabac::coordinator::pareto::pareto_front(&exact.results);
        assert_eq!(front_est, front_exact, "{method:?}");
        assert_eq!(est.best, exact.best, "{method:?}");
        assert!(est.best.is_some(), "{method:?} found no feasible point");
        // ...with identical *reported* sizes: every front/best member was
        // re-encoded through the exact path, so the bytes must match the
        // exact-always run bit for bit.
        for &i in &front_est {
            assert_eq!(
                est.results[i].sizes.compressed_weights,
                exact.results[i].sizes.compressed_weights,
                "{method:?} front member {i}"
            );
            assert_eq!(est.results[i].backend, "CABAC");
        }
        // Estimate quality: phase A priced every candidate within the
        // pinned tolerance of its real coded size (compare the est-sized
        // non-survivors against the exact run's real bytes).
        assert!(est.est_real_max_rel.unwrap() <= EST_RATE_TOLERANCE, "{method:?}");
        assert!(exact.est_real_max_rel.is_none());
        let mut estimated = 0usize;
        for (e, x) in est.results.iter().zip(&exact.results) {
            if e.backend == BACKEND_CABAC_ESTIMATED {
                estimated += 1;
                let est_w = e.sizes.compressed_weights as f64;
                let real_w = x.sizes.compressed_weights as f64;
                let rel = (est_w - real_w).abs() / real_w;
                assert!(
                    rel <= EST_RATE_TOLERANCE,
                    "{method:?} {:?}: est {est_w} vs real {real_w} ({rel:.4})",
                    e.candidate
                );
            }
        }
        // The tentpole's point: most of the grid was never trial-encoded.
        assert_eq!(est.exact_sized + estimated, est.results.len());
        assert!(
            estimated > 0,
            "{method:?}: estimate-first re-encoded the whole grid"
        );
        assert_eq!(exact.exact_sized, exact.results.len());
    }
}

#[test]
fn ints_accuracy_equals_decoded_stream_accuracy_for_every_candidate() {
    // The losslessness assumption phase A rests on, pinned per candidate:
    // reconstruct-from-quantizer-ints and reconstruct-from-decoded-stream
    // are the same network, so the oracle scores them identically (bitwise
    // — same f64, not merely close).
    let net = synth_net();
    let svc = oracle(&net);
    let c = cfg(SearchStrategy::ExactAlways);
    let mut checked = 0usize;
    for &delta in &[0.003f32, 0.006, 0.009] {
        for &lambda in &[0.0f32, 0.5, 4.0, 16.0] {
            let cand = Candidate {
                method: Method::DcV2,
                s: 0.0,
                delta,
                lambda,
                clusters: 0,
            };
            let compressed = compress_dc(&net, &cand, &c);
            let bytes = compressed.to_bytes_with(c.container);
            let decoded = CompressedNetwork::from_bytes_with(&bytes, 2).unwrap();
            for (a, b) in compressed.layers.iter().zip(&decoded.layers) {
                assert_eq!(a.ints, b.ints, "Δ={delta} λ={lambda}");
            }
            let acc_ints = svc.accuracy(&compressed.reconstruct(&net.name)).unwrap();
            let acc_stream = svc.accuracy(&decoded.reconstruct(&net.name)).unwrap();
            assert_eq!(acc_ints, acc_stream, "Δ={delta} λ={lambda}");
            checked += 1;
        }
    }
    assert_eq!(checked, 12);
}

#[test]
fn legacy_containers_fall_back_to_exact_pricing() {
    // The estimator models v3 bins; on a v1 container the estimate-first
    // strategy must silently run exact-always (every size real, no
    // estimate stats) rather than ranking under costs the stream wouldn't
    // spend.
    let net = synth_net();
    let svc = oracle(&net);
    let c = SearchConfig {
        container: ContainerPolicy::v1(),
        ..cfg(SearchStrategy::EstimateFirst)
    };
    let out = coordinator::search(&net, Method::DcV2, &c, &svc).unwrap();
    assert!(out.est_real_max_rel.is_none());
    assert_eq!(out.exact_sized, out.results.len());
    assert!(out.results.iter().all(|r| r.backend == "CABAC"));
}

#[test]
fn memo_budget_zero_still_matches_with_requantized_survivors() {
    // With the phase-B memo disabled the survivors are re-quantized instead
    // of re-encoded from kept ints — deterministic assignments make both
    // routes byte-identical.
    let net = synth_net();
    let svc = oracle(&net);
    let base = cfg(SearchStrategy::EstimateFirst);
    let kept = coordinator::search(&net, Method::DcV2, &base, &svc).unwrap();
    let requant = coordinator::search(
        &net,
        Method::DcV2,
        &SearchConfig {
            memo_budget_bytes: 0,
            ..base
        },
        &svc,
    )
    .unwrap();
    assert_eq!(kept.results.len(), requant.results.len());
    for (a, b) in kept.results.iter().zip(&requant.results) {
        assert_eq!(a.candidate, b.candidate);
        assert_eq!(a.sizes.compressed_weights, b.sizes.compressed_weights);
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.backend, b.backend);
    }
    assert_eq!(kept.best, requant.best);
    assert_eq!(kept.est_real_max_rel, requant.est_real_max_rel);
}
