#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Zero-allocation pin for the fused decode path: once a `DecodeArena` is
//! warm, repeated decodes of a same-shaped container must not touch the
//! heap at all — serial AND pooled.
//!
//! A counting global allocator wraps the system one; this file deliberately
//! holds a single `#[test]` so no sibling test thread can allocate during
//! the measured window.  The measured quantity is the MINIMUM allocation
//! delta over several repeats: the steady state is proven by any repeat
//! observing zero, while stray harness activity (timers, channel wakeups)
//! cannot produce a false PASS — only a retry.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use deepcabac::cabac::CodingConfig;
use deepcabac::model::{
    decode_network_into, CompressedNetwork, ContainerPolicy, DecodeArena, Kind, QuantizedLayer,
};
use deepcabac::util::Pcg64;

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn sample_container() -> Vec<u8> {
    let mut rng = Pcg64::new(0xA110C);
    let mk = |name: &str, rows: usize, cols: usize, rng: &mut Pcg64| QuantizedLayer {
        name: name.into(),
        kind: Kind::Dense,
        shape: vec![cols, rows],
        rows,
        cols,
        ints: (0..rows * cols)
            .map(|_| {
                if rng.next_f64() < 0.75 {
                    0
                } else {
                    rng.below(61) as i32 - 30
                }
            })
            .collect(),
        delta: 0.015625,
        bias: Some((0..rows).map(|r| r as f32 * 0.25).collect()),
    };
    let net = CompressedNetwork {
        name: "alloc_probe".into(),
        cfg: CodingConfig::default(),
        layers: vec![mk("fc1", 60, 200, &mut rng), mk("fc2", 25, 120, &mut rng)],
    };
    net.to_bytes_with(ContainerPolicy::v3(1024, 4))
}

fn min_alloc_delta(repeats: usize, mut f: impl FnMut()) -> usize {
    let mut min_delta = usize::MAX;
    for _ in 0..repeats {
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        f();
        let delta = ALLOC_CALLS.load(Ordering::SeqCst) - before;
        min_delta = min_delta.min(delta);
    }
    min_delta
}

#[test]
fn warmed_arena_fused_decode_is_allocation_free() {
    let bytes = sample_container();
    let expected = CompressedNetwork::from_bytes(&bytes)
        .unwrap()
        .reconstruct_named();

    let mut arena = DecodeArena::new();
    // Warm-up: first serial decode builds the skeleton + scratch (and the
    // global pool's OnceLock); second settles any lazily-grown capacity.
    decode_network_into(&bytes, 1, &mut arena).unwrap();
    decode_network_into(&bytes, 1, &mut arena).unwrap();

    let serial = min_alloc_delta(5, || {
        decode_network_into(&bytes, 1, &mut arena).unwrap();
    });
    assert_eq!(
        serial, 0,
        "steady-state serial fused decode performed {serial} heap allocations"
    );

    // Pooled path: warm once at t4 (spawns/parks the workers, grows the
    // per-worker scratch), then the steady state must also be clean — the
    // pool broadcasts a stack job, workers claim via an atomic cursor, and
    // results land in the arena's preallocated planes.
    decode_network_into(&bytes, 4, &mut arena).unwrap();
    decode_network_into(&bytes, 4, &mut arena).unwrap();
    let pooled = min_alloc_delta(5, || {
        decode_network_into(&bytes, 4, &mut arena).unwrap();
    });
    assert_eq!(
        pooled, 0,
        "steady-state pooled fused decode performed {pooled} heap allocations"
    );

    // And the allocation-free planes are still the right planes.
    let got = decode_network_into(&bytes, 4, &mut arena).unwrap();
    assert_eq!(got.layers.len(), expected.layers.len());
    for (a, b) in got.layers.iter().zip(&expected.layers) {
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.bias, b.bias);
    }
}
