#!/usr/bin/env python3
"""Golden-vector fixture generator for rust/tests/golden_vectors.rs.

A byte-exact Python transcription of the Rust coder (rust/src/cabac/{arith,
binarize,encoder}.rs and rust/src/model/bitstream.rs), used to pin the three
container wire formats as checked-in fixtures:

  golden_v1.dcb  - monolithic container, legacy bins (context sign,
                   per-bin EG suffix)
  golden_v2.dcb  - sliced container (slice_len 512), legacy bins
  golden_v3.dcb  - sliced container (slice_len 512), bypass fast path
                   (bypass sign, batched EG suffix)

and the DCB4 delta-container format (rust/src/model/delta.rs):

  golden_v4_base.dcb - a second network (fresh LCG seed, same geometry
                       family), v3 sliced container: the base the delta
                       below is pinned against
  golden_v4.dcb      - v4 delta onto golden_v4_base.dcb: fc1 carries a
                       sparse residual plane (sliced bypass payload),
                       big rides the skip-flag table (geometry header
                       only, no payload fields); header pins the base's
                       crc32 and FNV-1a shape key

and the ingest-side fixture for rust/tests/encode_fuzz.rs:

  golden.nwf         - 3-layer .nwf checkpoint (model/nwf.rs wire format)
                       covering every flag combination (fisher / hessian /
                       bias), an empty plane, and IEEE-754 specials planted
                       bitwise (NaN / +-Inf / subnormal / -0.0 / f32::MAX)
                       so the non-finite policy and the exhaustive
                       single-byte corruption sweep have a committed,
                       adversarial-but-valid target

The generator decodes everything back with an independent Python decoder
mirror and CRC-checks the containers before writing, so a transcription slip
fails here rather than in CI.  The network payload is derived from the same
LCG that rust/tests/golden_vectors.rs re-implements.

Regenerate (only when intentionally changing a wire format!) with:
    python3 rust/tests/fixtures/golden/gen_golden.py
"""

import os
import struct
import zlib

M32 = 0xFFFFFFFF
M64 = 0xFFFFFFFFFFFFFFFF
PROB_BITS = 12
PROB_ONE = 1 << PROB_BITS
PROB_INIT = PROB_ONE // 2
ADAPT_SHIFT = 5
TOP = 1 << 24
BYPASS_CHUNK = 16

MAX_ABS_GR = 10
EG_CONTEXTS = 16
SLICE_LEN = 512


# --- arith.rs ---------------------------------------------------------------

class Context:
    __slots__ = ("p0",)

    def __init__(self):
        self.p0 = PROB_INIT

    def update(self, bit):
        if bit:
            self.p0 -= self.p0 >> ADAPT_SHIFT
        else:
            self.p0 += (PROB_ONE - self.p0) >> ADAPT_SHIFT


class Encoder:
    def __init__(self):
        self.low = 0
        self.range = M32  # u32::MAX
        self.cache = 0
        self.pending = 0
        self.first = True
        self.out = bytearray()

    def shift_low(self):
        if (self.low & M32) < 0xFF000000 or (self.low >> 32) != 0:
            carry = (self.low >> 32) & 0xFF
            if not self.first:
                self.out.append((self.cache + carry) & 0xFF)
            else:
                self.out.append(carry)  # cache==0 on first flush
                self.first = False
            while self.pending > 0:
                self.out.append((0xFF + carry) & 0xFF)
                self.pending -= 1
            self.cache = (self.low >> 24) & 0xFF
        else:
            self.pending += 1
        self.low = (self.low << 8) & M32

    def encode(self, ctx, bit):
        bound = (self.range >> PROB_BITS) * ctx.p0
        if bit:
            self.low += bound
            self.range -= bound
        else:
            self.range = bound
        ctx.update(bit)
        while self.range < TOP:
            self.range = (self.range << 8) & M32
            self.shift_low()

    def encode_bypass(self, bit):
        self.range >>= 1
        if bit:
            self.low += self.range
        while self.range < TOP:
            self.range = (self.range << 8) & M32
            self.shift_low()

    def encode_bypass_bits(self, v, n):
        rem = n
        while rem > 0:
            k = min(rem, BYPASS_CHUNK)
            rem -= k
            chunk = (v >> rem) & ((1 << k) - 1)
            self.range >>= k
            self.low += chunk * self.range
            while self.range < TOP:
                self.range = (self.range << 8) & M32
                self.shift_low()

    def encode_bypass_bits_serial(self, v, n):
        for i in range(n - 1, -1, -1):
            self.encode_bypass((v >> i) & 1 == 1)

    def finish(self):
        for _ in range(5):
            self.shift_low()
        return bytes(self.out)


class Decoder:
    def __init__(self, buf):
        self.buf = buf
        self.pos = 1  # skip the priming byte
        self.code = 0
        self.range = M32
        for _ in range(4):
            self.code = ((self.code << 8) | self.next_byte()) & M32

    def next_byte(self):
        b = self.buf[self.pos] if self.pos < len(self.buf) else 0
        self.pos += 1
        return b

    def decode(self, ctx):
        bound = (self.range >> PROB_BITS) * ctx.p0
        bit = self.code >= bound
        if bit:
            self.code -= bound
            self.range -= bound
        else:
            self.range = bound
        ctx.update(bit)
        while self.range < TOP:
            self.range = (self.range << 8) & M32
            self.code = ((self.code << 8) | self.next_byte()) & M32
        return bit

    def decode_bypass(self):
        self.range >>= 1
        bit = self.code >= self.range
        if bit:
            self.code -= self.range
        while self.range < TOP:
            self.range = (self.range << 8) & M32
            self.code = ((self.code << 8) | self.next_byte()) & M32
        return bit

    def decode_bypass_bits(self, n):
        v = 0
        rem = n
        while rem > 0:
            k = min(rem, BYPASS_CHUNK)
            rem -= k
            self.range >>= k
            mask = (1 << k) - 1
            chunk = min(self.code // self.range, mask)
            self.code -= chunk * self.range
            v = (v << k) | chunk
            while self.range < TOP:
                self.range = (self.range << 8) & M32
                self.code = ((self.code << 8) | self.next_byte()) & M32
        return v

    def decode_bypass_bits_serial(self, n):
        v = 0
        for _ in range(n):
            v = (v << 1) | (1 if self.decode_bypass() else 0)
        return v


# --- context.rs / binarize.rs ----------------------------------------------

class WeightContexts:
    def __init__(self):
        self.sig = [Context(), Context(), Context()]
        self.sign = Context()
        self.gr = [Context() for _ in range(MAX_ABS_GR)]
        self.eg = [Context() for _ in range(EG_CONTEXTS)]


class SigHistory:
    def __init__(self):
        self.prev = [False, False]

    def ctx_index(self):
        return int(self.prev[0]) + int(self.prev[1])

    def push(self, significant):
        self.prev = [self.prev[1], significant]


def bit_length_minus_one(u):
    # Rust: 31 - u.leading_zeros() for u: u32, u >= 1
    return u.bit_length() - 1


def encode_int(e, ctxs, hist, v, legacy):
    sig = v != 0
    e.encode(ctxs.sig[hist.ctx_index()], sig)
    hist.push(sig)
    if not sig:
        return
    if legacy:
        e.encode(ctxs.sign, v < 0)
    else:
        e.encode_bypass(v < 0)
    a = abs(v)
    n = MAX_ABS_GR
    for i in range(1, n + 1):
        gt = a > i
        e.encode(ctxs.gr[i - 1], gt)
        if not gt:
            return
    u = a - n  # r + 1, >= 1
    k = bit_length_minus_one(u)
    m = EG_CONTEXTS
    for p in range(k):
        if p < m:
            e.encode(ctxs.eg[p], True)
        else:
            e.encode_bypass(True)
    if k < m:
        e.encode(ctxs.eg[k], False)
    else:
        e.encode_bypass(False)
    suffix = u & ((1 << k) - 1)
    if legacy:
        e.encode_bypass_bits_serial(suffix, k)
    else:
        e.encode_bypass_bits(suffix, k)


def decode_int(d, ctxs, hist, legacy):
    sig = d.decode(ctxs.sig[hist.ctx_index()])
    hist.push(sig)
    if not sig:
        return 0
    neg = d.decode(ctxs.sign) if legacy else d.decode_bypass()
    n = MAX_ABS_GR
    a = 1
    all_greater = True
    for i in range(1, n + 1):
        if not d.decode(ctxs.gr[i - 1]):
            a = i
            all_greater = False
            break
    if all_greater:
        m = EG_CONTEXTS
        k = 0
        while True:
            one = d.decode(ctxs.eg[k]) if k < m else d.decode_bypass()
            if not one:
                break
            k += 1
            assert k < 32, "corrupt stream"
        suffix = d.decode_bypass_bits_serial(k) if legacy else d.decode_bypass_bits(k)
        a = ((1 << k) | suffix) + n
    return -a if neg else a


def encode_layer(values, legacy):
    ctxs, hist, e = WeightContexts(), SigHistory(), Encoder()
    for v in values:
        encode_int(e, ctxs, hist, v, legacy)
    return e.finish()


def decode_layer(raw, count, legacy):
    ctxs, hist, d = WeightContexts(), SigHistory(), Decoder(raw)
    return [decode_int(d, ctxs, hist, legacy) for _ in range(count)]


# --- model/bitstream.rs -----------------------------------------------------

def assemble_sliced(slice_len, payloads):
    out = bytearray()
    out += struct.pack("<I", max(slice_len, 1))
    out += struct.pack("<I", len(payloads))
    for p in payloads:
        out += struct.pack("<I", len(p))
        out += p
    return bytes(out)


def layer_payload(ints, version):
    legacy = version != 3
    if version == 1:
        return encode_layer(ints, legacy)
    chunks = [ints[i:i + SLICE_LEN] for i in range(0, len(ints), SLICE_LEN)]
    return assemble_sliced(SLICE_LEN, [encode_layer(c, legacy) for c in chunks])


def to_bytes(net, version):
    body = bytearray()
    body.append(version)
    body += struct.pack("<H", len(net["name"]))
    body += net["name"].encode()
    body += struct.pack("<I", MAX_ABS_GR)
    body += struct.pack("<I", EG_CONTEXTS)
    body += struct.pack("<I", len(net["layers"]))
    for l in net["layers"]:
        body += struct.pack("<H", len(l["name"]))
        body += l["name"].encode()
        body.append(l["kind"])
        body.append(len(l["shape"]))
        for d in l["shape"]:
            body += struct.pack("<I", d)
        body += struct.pack("<I", l["rows"])
        body += struct.pack("<I", l["cols"])
        body += struct.pack("<f", l["delta"])
        body.append(1 if l["bias"] is not None else 0)
        if l["bias"] is not None:
            body += struct.pack("<I", len(l["bias"]))
            for x in l["bias"]:
                body += struct.pack("<f", x)
        payload = layer_payload(l["ints"], version)
        body += struct.pack("<I", len(payload))
        body += payload
    return b"DCB1" + bytes(body) + struct.pack("<I", zlib.crc32(bytes(body)) & M32)


def fnv_shape_key(net):
    """Mirror of ContainerProbe::shape_key / container_shape_key: FNV-1a
    over a length-prefixed field stream (version and deltas excluded)."""
    h = 0xCBF29CE484222325

    def eat(bs):
        nonlocal h
        for b in bs:
            h = ((h ^ b) * 0x100000001B3) & M64

    def eat_u64(v):
        eat(struct.pack("<Q", v & M64))

    eat_u64(len(net["name"]))
    eat(net["name"].encode())
    eat_u64(MAX_ABS_GR)
    eat_u64(EG_CONTEXTS)
    eat_u64(len(net["layers"]))
    for l in net["layers"]:
        eat_u64(len(l["name"]))
        eat(l["name"].encode())
        eat_u64(l["kind"])
        eat_u64(l["rows"])
        eat_u64(l["cols"])
        eat_u64(len(l["shape"]))
        for d in l["shape"]:
            eat_u64(d)
        eat_u64(len(l["bias"]) if l["bias"] is not None else 0)
    return h


def delta_to_bytes(delta, base_crc32, base_shape_key):
    """Mirror of CompressedDelta::to_bytes_with (v4 wire layout): base
    hash + shape key after the coding config, LSB-first skip-flag table
    after the layer count, geometry headers always, payload fields only
    for coded (non-skipped) layers."""
    body = bytearray()
    body.append(4)
    body += struct.pack("<H", len(delta["name"]))
    body += delta["name"].encode()
    body += struct.pack("<I", MAX_ABS_GR)
    body += struct.pack("<I", EG_CONTEXTS)
    body += struct.pack("<I", base_crc32 & M32)
    body += struct.pack("<Q", base_shape_key & M64)
    body += struct.pack("<I", len(delta["layers"]))
    skip = bytearray(-(-len(delta["layers"]) // 8))
    for i, l in enumerate(delta["layers"]):
        if l["ints"] is None:
            skip[i // 8] |= 1 << (i % 8)
    body += skip
    for l in delta["layers"]:
        body += struct.pack("<H", len(l["name"]))
        body += l["name"].encode()
        body.append(l["kind"])
        body.append(len(l["shape"]))
        for d in l["shape"]:
            body += struct.pack("<I", d)
        body += struct.pack("<I", l["rows"])
        body += struct.pack("<I", l["cols"])
        body += struct.pack("<f", l["delta"])
        body.append(1 if l["bias"] is not None else 0)
        if l["bias"] is not None:
            body += struct.pack("<I", len(l["bias"]))
            for x in l["bias"]:
                body += struct.pack("<f", x)
        if l["ints"] is not None:
            # residual payloads always use the sliced bypass path
            chunks = [l["ints"][i:i + SLICE_LEN]
                      for i in range(0, len(l["ints"]), SLICE_LEN)]
            payload = assemble_sliced(
                SLICE_LEN, [encode_layer(c, False) for c in chunks])
            body += struct.pack("<I", len(payload))
            body += payload
    return b"DCB1" + bytes(body) + struct.pack("<I", zlib.crc32(bytes(body)) & M32)


def parse_and_decode_delta(raw):
    """Independent decode mirror of CompressedDelta::from_bytes."""
    assert raw[:4] == b"DCB1"
    body = raw[4:-4]
    assert struct.unpack("<I", raw[-4:])[0] == zlib.crc32(body) & M32, "crc"
    pos = 0

    def take(n):
        nonlocal pos
        assert pos + n <= len(body), "truncated"
        s = body[pos:pos + n]
        pos += n
        return s

    assert take(1)[0] == 4
    name = take(struct.unpack("<H", take(2))[0]).decode()
    assert struct.unpack("<I", take(4))[0] == MAX_ABS_GR
    assert struct.unpack("<I", take(4))[0] == EG_CONTEXTS
    base_crc32 = struct.unpack("<I", take(4))[0]
    base_shape_key = struct.unpack("<Q", take(8))[0]
    n_layers = struct.unpack("<I", take(4))[0]
    skip = take(-(-n_layers // 8))
    layers = []
    for idx in range(n_layers):
        skipped = (skip[idx // 8] >> (idx % 8)) & 1 == 1
        lname = take(struct.unpack("<H", take(2))[0]).decode()
        kind = take(1)[0]
        nd = take(1)[0]
        shape = [struct.unpack("<I", take(4))[0] for _ in range(nd)]
        rows = struct.unpack("<I", take(4))[0]
        cols = struct.unpack("<I", take(4))[0]
        delta = struct.unpack("<f", take(4))[0]
        bias = None
        if take(1)[0]:
            blen = struct.unpack("<I", take(4))[0]
            bias = [struct.unpack("<f", take(4))[0] for _ in range(blen)]
        ints = None
        if not skipped:
            payload = take(struct.unpack("<I", take(4))[0])
            count = rows * cols
            slice_len, n_slices = struct.unpack("<II", payload[:8])
            assert slice_len == SLICE_LEN
            assert n_slices == -(-count // slice_len)
            p, ints = 8, []
            for i in range(n_slices):
                ln = struct.unpack("<I", payload[p:p + 4])[0]
                p += 4
                nsym = count - slice_len * (n_slices - 1) if i + 1 == n_slices else slice_len
                ints += decode_layer(payload[p:p + ln], nsym, False)
                p += ln
            assert p == len(payload)
        layers.append(
            dict(name=lname, kind=kind, shape=shape, rows=rows, cols=cols,
                 ints=ints, delta=delta, bias=bias)
        )
    assert pos == len(body), "trailing garbage"
    return dict(name=name, base_crc32=base_crc32,
                base_shape_key=base_shape_key, layers=layers)


def parse_and_decode(raw):
    """Independent decode mirror of CompressedNetwork::from_bytes."""
    assert raw[:4] == b"DCB1"
    body = raw[4:-4]
    assert struct.unpack("<I", raw[-4:])[0] == zlib.crc32(body) & M32, "crc"
    pos = 0

    def take(n):
        nonlocal pos
        assert pos + n <= len(body), "truncated"
        s = body[pos:pos + n]
        pos += n
        return s

    version = take(1)[0]
    assert version in (1, 2, 3)
    legacy = version != 3
    name = take(struct.unpack("<H", take(2))[0]).decode()
    max_abs_gr, eg_contexts, n_layers = (
        struct.unpack("<I", take(4))[0] for _ in range(3)
    )
    assert (max_abs_gr, eg_contexts) == (MAX_ABS_GR, EG_CONTEXTS)
    layers = []
    for _ in range(n_layers):
        lname = take(struct.unpack("<H", take(2))[0]).decode()
        kind = take(1)[0]
        nd = take(1)[0]
        shape = [struct.unpack("<I", take(4))[0] for _ in range(nd)]
        rows = struct.unpack("<I", take(4))[0]
        cols = struct.unpack("<I", take(4))[0]
        delta = struct.unpack("<f", take(4))[0]
        bias = None
        if take(1)[0]:
            blen = struct.unpack("<I", take(4))[0]
            bias = [struct.unpack("<f", take(4))[0] for _ in range(blen)]
        payload = take(struct.unpack("<I", take(4))[0])
        count = rows * cols
        if version == 1:
            ints = decode_layer(payload, count, legacy)
        else:
            slice_len, n_slices = struct.unpack("<II", payload[:8])
            assert slice_len == SLICE_LEN
            assert n_slices == -(-count // slice_len)
            p, ints = 8, []
            for i in range(n_slices):
                ln = struct.unpack("<I", payload[p:p + 4])[0]
                p += 4
                nsym = count - slice_len * (n_slices - 1) if i + 1 == n_slices else slice_len
                ints += decode_layer(payload[p:p + ln], nsym, legacy)
                p += ln
            assert p == len(payload)
        layers.append(
            dict(name=lname, kind=kind, shape=shape, rows=rows, cols=cols,
                 ints=ints, delta=delta, bias=bias)
        )
    assert pos == len(body), "trailing garbage"
    return dict(name=name, layers=layers)


# --- deterministic payload (mirrored in golden_vectors.rs) ------------------

class Lcg:
    """Tiny LCG shared verbatim with the Rust test: 64-bit state, top bits."""

    def __init__(self, seed):
        self.s = seed & M64

    def next(self):
        self.s = (self.s * 6364136223846793005 + 1442695040888963407) & M64
        return self.s >> 33


def gen_ints(lcg, count, mag_cap):
    out = []
    for _ in range(count):
        if lcg.next() % 10 < 6:
            out.append(0)
        else:
            mag = int(lcg.next() % mag_cap) + 1
            out.append(-mag if lcg.next() & 1 else mag)
    return out


def golden_network():
    lcg = Lcg(0xDCB3)
    fc1 = dict(
        name="fc1", kind=0, shape=[50, 40], rows=40, cols=50,
        ints=gen_ints(lcg, 2000, 35), delta=0.03125,
        bias=[float(int(lcg.next() % 64) - 32) / 16.0 for _ in range(40)],
    )
    big = dict(
        name="big", kind=1, shape=[50, 30], rows=30, cols=50,
        ints=gen_ints(lcg, 1500, 250000), delta=0.0078125, bias=None,
    )
    return dict(name="golden_net", layers=[fc1, big])


def golden_v4_base_network():
    """Fresh-seed sibling of golden_network (same geometry family) — the
    base container the golden delta is pinned against."""
    lcg = Lcg(0xDCB4)
    fc1 = dict(
        name="fc1", kind=0, shape=[50, 40], rows=40, cols=50,
        ints=gen_ints(lcg, 2000, 35), delta=0.03125,
        bias=[float(int(lcg.next() % 64) - 32) / 16.0 for _ in range(40)],
    )
    big = dict(
        name="big", kind=1, shape=[50, 30], rows=30, cols=50,
        ints=gen_ints(lcg, 1500, 250000), delta=0.0078125, bias=None,
    )
    return dict(name="golden_base", layers=[fc1, big])


def gen_residual(lcg, count, mag_cap):
    """Sparse residual plane (~10% nonzero, small magnitudes) — mirrored
    verbatim in golden_vectors.rs."""
    out = []
    for _ in range(count):
        if lcg.next() % 10 == 0:
            mag = int(lcg.next() % mag_cap) + 1
            out.append(-mag if lcg.next() & 1 else mag)
        else:
            out.append(0)
    return out


def golden_v4_delta(base):
    """Delta onto golden_v4_base: fc1 carries a sparse residual, big is
    skipped (geometry header only).  No replacement biases."""
    lcg = Lcg(0xDCB5)
    fc1, big = base["layers"]
    return dict(
        name=base["name"],
        layers=[
            dict(name=fc1["name"], kind=fc1["kind"], shape=fc1["shape"],
                 rows=fc1["rows"], cols=fc1["cols"],
                 ints=gen_residual(lcg, fc1["rows"] * fc1["cols"], 4),
                 delta=0.015625, bias=None),
            dict(name=big["name"], kind=big["kind"], shape=big["shape"],
                 rows=big["rows"], cols=big["cols"],
                 ints=None, delta=0.0, bias=None),
        ],
    )


# --- golden .nwf ingest fixture (model/nwf.rs wire format) ------------------

NAN_BITS = 0x7FC00000
POS_INF_BITS = 0x7F800000
NEG_INF_BITS = 0xFF800000
SUBNORMAL_BITS = 0x00000001
NEG_ZERO_BITS = 0x80000000
F32_MAX_BITS = 0x7F7FFFFF


def f32_bits(v):
    return struct.unpack("<I", struct.pack("<f", v))[0]


def gen_weight_bits(lcg, count):
    """Deterministic small weights in [-0.2, 0.2], stored as bit patterns
    so special-value planting is byte-exact."""
    return [f32_bits(((lcg.next() % 2001) - 1000) / 5000.0) for _ in range(count)]


def golden_nwf_layers():
    """Structurally valid, value-adversarial: conv1 is salted with the full
    IEEE-754 special set (weights + fisher + bias), fc1 is clean with a
    hessian plane, tiny is an empty plane (rows=0)."""
    lcg = Lcg(0xDCB6)
    w = gen_weight_bits(lcg, 72)
    for i, bits in zip((3, 10, 17, 30, 45, 60),
                       (NAN_BITS, POS_INF_BITS, NEG_INF_BITS,
                        SUBNORMAL_BITS, NEG_ZERO_BITS, F32_MAX_BITS)):
        w[i] = bits
    fisher = [f32_bits((lcg.next() % 1000) / 500.0 + 0.01) for _ in range(72)]
    fisher[5] = NAN_BITS      # invalid importance: non-finite
    fisher[40] = f32_bits(-1.0)  # invalid importance: negative
    bias = gen_weight_bits(lcg, 8)
    bias[2] = POS_INF_BITS
    conv1 = dict(name="conv1", kind=1, shape=[3, 3, 2, 4], rows=8, cols=9,
                 weights=w, fisher=fisher, hessian=None, bias=bias)
    fc1 = dict(name="fc1", kind=0, shape=[24, 10], rows=10, cols=24,
               weights=gen_weight_bits(lcg, 240), fisher=None,
               hessian=[f32_bits((lcg.next() % 1000) / 500.0 + 0.01)
                        for _ in range(240)],
               bias=None)
    tiny = dict(name="tiny", kind=2, shape=[0, 5], rows=0, cols=5,
                weights=[], fisher=None, hessian=None, bias=None)
    return [conv1, fc1, tiny]


def nwf_to_bytes(layers):
    """Mirror of model/nwf.rs::write_nwf (planes given as u32 bit lists)."""
    body = bytearray()
    body += struct.pack("<I", len(layers))
    for l in layers:
        body += struct.pack("<H", len(l["name"]))
        body += l["name"].encode()
        body += struct.pack("<BB", l["kind"], len(l["shape"]))
        for d in l["shape"]:
            body += struct.pack("<I", d)
        body += struct.pack("<II", l["rows"], l["cols"])
        flags = (int(l["fisher"] is not None)
                 | (int(l["hessian"] is not None) << 1)
                 | (int(l["bias"] is not None) << 2))
        body += struct.pack("<B", flags)
        for bits in l["weights"]:
            body += struct.pack("<I", bits)
        for plane in (l["fisher"], l["hessian"]):
            if plane is not None:
                for bits in plane:
                    body += struct.pack("<I", bits)
        if l["bias"] is not None:
            body += struct.pack("<I", len(l["bias"]))
            for bits in l["bias"]:
                body += struct.pack("<I", bits)
    return b"NWF1" + bytes(body) + struct.pack("<I", zlib.crc32(bytes(body)) & M32)


def parse_nwf_mirror(raw):
    """Independent parse mirror of model/nwf.rs::parse_nwf."""
    assert raw[:4] == b"NWF1"
    body = raw[4:-4]
    assert struct.unpack("<I", raw[-4:])[0] == zlib.crc32(body) & M32, "crc"
    pos = 0

    def take(n):
        nonlocal pos
        assert pos + n <= len(body), "truncated"
        s = body[pos:pos + n]
        pos += n
        return s

    n_layers = struct.unpack("<I", take(4))[0]
    layers = []
    for _ in range(n_layers):
        name = take(struct.unpack("<H", take(2))[0]).decode()
        kind, nd = struct.unpack("<BB", take(2))
        shape = [struct.unpack("<I", take(4))[0] for _ in range(nd)]
        rows, cols = struct.unpack("<II", take(8))
        (flags,) = struct.unpack("<B", take(1))
        assert flags & ~0x07 == 0, "unknown flag bits"
        n = rows * cols
        plane = lambda count: list(struct.unpack(f"<{count}I", take(4 * count)))
        weights = plane(n)
        fisher = plane(n) if flags & 1 else None
        hessian = plane(n) if flags & 2 else None
        bias = None
        if flags & 4:
            bias = plane(struct.unpack("<I", take(4))[0])
        prod = 1
        for d in shape:
            prod *= d
        assert prod == n, (name, shape, n)
        layers.append(dict(name=name, kind=kind, shape=shape, rows=rows,
                           cols=cols, weights=weights, fisher=fisher,
                           hessian=hessian, bias=bias))
    assert pos == len(body), "trailing garbage"
    return layers


def main():
    here = os.path.dirname(os.path.abspath(__file__))
    net = golden_network()
    # sanity: the big layer must exercise batched suffixes wider than one
    # 16-bit chunk (k up to 17)
    widest = max(
        (abs(v) - MAX_ABS_GR).bit_length() - 1
        for v in net["layers"][1]["ints"] if abs(v) > MAX_ABS_GR
    )
    assert widest > BYPASS_CHUNK, f"need k > {BYPASS_CHUNK}, got {widest}"

    for version in (1, 2, 3):
        raw = to_bytes(net, version)
        back = parse_and_decode(raw)
        assert back["name"] == net["name"]
        for l, b in zip(net["layers"], back["layers"]):
            for key in ("name", "kind", "shape", "rows", "cols", "ints"):
                assert l[key] == b[key], (version, l["name"], key)
            assert struct.pack("<f", l["delta"]) == struct.pack("<f", b["delta"])
            if l["bias"] is None:
                assert b["bias"] is None
            else:
                assert [struct.pack("<f", x) for x in l["bias"]] == [
                    struct.pack("<f", x) for x in b["bias"]
                ]
        path = os.path.join(here, f"golden_v{version}.dcb")
        with open(path, "wb") as f:
            f.write(raw)
        print(f"golden_v{version}.dcb: {len(raw)} bytes, "
              f"crc32 {zlib.crc32(raw) & M32:08x}")

    # --- DCB4 delta fixtures -------------------------------------------
    base = golden_v4_base_network()
    base_raw = to_bytes(base, 3)
    back = parse_and_decode(base_raw)
    for l, b in zip(base["layers"], back["layers"]):
        assert l["ints"] == b["ints"], ("v4 base", l["name"])
    base_crc = zlib.crc32(base_raw) & M32
    base_key = fnv_shape_key(base)

    delta = golden_v4_delta(base)
    draw = delta_to_bytes(delta, base_crc, base_key)
    dback = parse_and_decode_delta(draw)
    assert dback["name"] == delta["name"]
    assert dback["base_crc32"] == base_crc
    assert dback["base_shape_key"] == base_key
    for l, b in zip(delta["layers"], dback["layers"]):
        for key in ("name", "kind", "shape", "rows", "cols", "ints"):
            assert l[key] == b[key], ("v4", l["name"], key)
        assert struct.pack("<f", l["delta"]) == struct.pack("<f", b["delta"])
        assert l["bias"] is None and b["bias"] is None
    assert dback["layers"][0]["ints"] is not None
    assert dback["layers"][1]["ints"] is None, "big must ride the skip table"
    nz = sum(1 for v in delta["layers"][0]["ints"] if v != 0)
    assert 0 < nz < len(delta["layers"][0]["ints"]) // 5, f"nz={nz}"

    for fname, raw in (("golden_v4_base.dcb", base_raw), ("golden_v4.dcb", draw)):
        with open(os.path.join(here, fname), "wb") as f:
            f.write(raw)
        print(f"{fname}: {len(raw)} bytes, crc32 {zlib.crc32(raw) & M32:08x}")
    print(f"base crc32 {base_crc:08x}, base shape key {base_key:016x}")

    # --- golden .nwf ingest fixture ------------------------------------
    nwf_layers = golden_nwf_layers()
    nwf_raw = nwf_to_bytes(nwf_layers)
    nwf_back = parse_nwf_mirror(nwf_raw)
    assert len(nwf_back) == 3
    for l, b in zip(nwf_layers, nwf_back):
        for key in ("name", "kind", "shape", "rows", "cols", "weights",
                    "fisher", "hessian", "bias"):
            assert l[key] == b[key], ("nwf", l["name"], key)
    # the specials must be present bit-exactly (encode_fuzz.rs pins the
    # same census against parse_nwf)
    conv1 = nwf_back[0]
    assert conv1["weights"][3] == NAN_BITS
    assert conv1["weights"][10] == POS_INF_BITS
    assert conv1["weights"][17] == NEG_INF_BITS
    assert conv1["weights"][30] == SUBNORMAL_BITS
    assert conv1["weights"][45] == NEG_ZERO_BITS
    assert conv1["weights"][60] == F32_MAX_BITS
    assert conv1["fisher"][5] == NAN_BITS
    assert conv1["bias"][2] == POS_INF_BITS
    assert all((b >> 23) & 0xFF != 0xFF
               for b in nwf_back[1]["weights"]), "fc1 must be clean"
    assert nwf_back[2]["weights"] == [] and nwf_back[2]["rows"] == 0
    with open(os.path.join(here, "golden.nwf"), "wb") as f:
        f.write(nwf_raw)
    print(f"golden.nwf: {len(nwf_raw)} bytes, "
          f"crc32 {zlib.crc32(nwf_raw) & M32:08x}")


if __name__ == "__main__":
    main()
