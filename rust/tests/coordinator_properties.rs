#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Property tests on coordinator invariants: quantizer monotonicity, RDOQ
//! optimality vs NN, Pareto-front correctness, Lloyd objective descent.

use deepcabac::cabac::context::{CodingConfig, WeightContexts};
use deepcabac::cabac::estimator::CostTable;
use deepcabac::coordinator::config::{Candidate, Method};
use deepcabac::coordinator::pareto::{best_within_tolerance, pareto_front};
use deepcabac::coordinator::pipeline::CandidateResult;
use deepcabac::metrics::Sizes;
use deepcabac::quant::rd::{argmin_rd, rd_quantize_layer, RdParams};
use deepcabac::quant::uniform;
use deepcabac::quant::weighted_lloyd;
use deepcabac::testutil::{check, check_slice, gen, Config};
use deepcabac::util::Pcg64;

#[test]
fn prop_rdoq_objective_never_worse_than_nn() {
    // For any weight/importance/λ, the RDOQ pick's objective under the same
    // cost table must be <= the nearest-neighbour pick's.
    check(
        Config {
            cases: 300,
            seed: 0xF1,
        },
        |rng: &mut Pcg64| {
            (
                rng.uniform(-1.0, 1.0) as f32,
                rng.uniform(0.0, 10.0) as f32,
                rng.uniform(1e-4, 0.2) as f32,
                rng.uniform(0.0, 0.1) as f32,
            )
        },
        |&(w, f, delta, lambda)| {
            let ctxs = WeightContexts::new(CodingConfig::default());
            let table = CostTable::build(&ctxs, 0, 256);
            let pick = argmin_rd(w, f, delta, lambda, &table);
            let nn = ((w / delta).round() as i32).clamp(-256, 256);
            let obj = |i: i32| {
                let d = w - delta * i as f32;
                f * d * d + lambda * table.bits(i)
            };
            obj(pick) <= obj(nn) + 1e-5
        },
    );
}

#[test]
fn prop_rdoq_lambda_monotone_sparsity() {
    // More rate pressure never decreases the number of zeros (on the same
    // weights, same Δ, frozen-table mode).
    check_slice(
        Config {
            cases: 40,
            seed: 0xF2,
        },
        gen::weights,
        |w| {
            if w.is_empty() {
                return true;
            }
            let max_abs = w.iter().fold(0f32, |m, &x| m.max(x.abs()));
            if max_abs == 0.0 {
                return true;
            }
            let delta = max_abs / 64.0;
            let zeros = |lambda: f32| {
                let mut p = RdParams::new(delta, lambda, 128);
                p.refresh = usize::MAX; // frozen table: isolates the λ effect
                rd_quantize_layer(w, &[], &p)
                    .iter()
                    .filter(|&&i| i == 0)
                    .count()
            };
            let z0 = zeros(0.0);
            let z1 = zeros(delta * delta * 4.0);
            let z2 = zeros(delta * delta * 64.0);
            z0 <= z1 && z1 <= z2
        },
    );
}

#[test]
fn prop_uniform_reconstruction_error_bounded() {
    check_slice(
        Config {
            cases: 80,
            seed: 0xF3,
        },
        gen::weights,
        |w| {
            if w.is_empty() {
                return true;
            }
            let max_abs = w.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let delta = uniform::delta_for_clusters(max_abs, 255);
            let ints = uniform::assign_nearest(w, delta, 127);
            w.iter().zip(&ints).all(|(&wi, &ii)| {
                let q = ii as f32 * delta;
                (wi - q).abs() <= delta / 2.0 + max_abs * 1e-5
            })
        },
    );
}

#[test]
fn prop_lloyd_objective_decreases_with_iterations() {
    check_slice(
        Config {
            cases: 20,
            seed: 0xF4,
        },
        gen::weights,
        |w| {
            if w.len() < 64 {
                return true;
            }
            let f = vec![1.0f32; w.len()];
            // 2 iterations vs 12: more iterations never worsen J_λ.
            let a = weighted_lloyd(w, &f, 16, 0.01, 2, 0.0);
            let b = weighted_lloyd(w, &f, 16, 0.01, 12, 0.0);
            b.objective <= a.objective + 1e-6 * a.objective.abs().max(1.0)
        },
    );
}

#[test]
fn prop_pareto_front_sound_and_complete() {
    check(
        Config {
            cases: 100,
            seed: 0xF5,
        },
        |rng: &mut Pcg64| {
            let n = 1 + rng.below(40) as usize;
            (0..n)
                .map(|_| (rng.next_f64(), rng.below(100_000) as usize))
                .collect::<Vec<(f64, usize)>>()
        },
        |points| {
            let results: Vec<CandidateResult> = points
                .iter()
                .map(|&(acc, size)| CandidateResult {
                    candidate: Candidate {
                        method: Method::DcV2,
                        s: 0.0,
                        delta: 0.01,
                        lambda: 0.0,
                        clusters: 0,
                    },
                    sizes: Sizes {
                        original_weights: 1_000_000,
                        bias: 0,
                        compressed_weights: size,
                    },
                    accuracy: acc,
                    backend: "CABAC",
                })
                .collect();
            let front = pareto_front(&results);
            // soundness: no front member dominated by any point
            for &i in &front {
                for (j, b) in results.iter().enumerate() {
                    if i == j {
                        continue;
                    }
                    let a = &results[i];
                    let dominates = b.accuracy >= a.accuracy
                        && b.sizes.compressed_weights <= a.sizes.compressed_weights
                        && (b.accuracy > a.accuracy
                            || b.sizes.compressed_weights < a.sizes.compressed_weights);
                    if dominates {
                        return false;
                    }
                }
            }
            // completeness: every non-front point is dominated by someone
            for (i, a) in results.iter().enumerate() {
                if front.contains(&i) {
                    continue;
                }
                let dominated = results.iter().enumerate().any(|(j, b)| {
                    i != j
                        && b.accuracy >= a.accuracy
                        && b.sizes.compressed_weights <= a.sizes.compressed_weights
                        && (b.accuracy > a.accuracy
                            || b.sizes.compressed_weights < a.sizes.compressed_weights)
                });
                if !dominated {
                    return false;
                }
            }
            // tolerance pick is feasible + minimal
            if let Some(best) = best_within_tolerance(&results, 0.5, 0.1) {
                if best.accuracy < 0.4 {
                    return false;
                }
                for r in &results {
                    if r.accuracy >= 0.4
                        && r.sizes.compressed_weights < best.sizes.compressed_weights
                    {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_parallel_map_equals_serial() {
    use deepcabac::coordinator::parallel::parallel_map;
    check_slice(
        Config {
            cases: 40,
            seed: 0xF6,
        },
        gen::sparse_symbols,
        |s| {
            let par = parallel_map(s, 7, |&x| x as i64 * 3 - 1);
            let ser: Vec<i64> = s.iter().map(|&x| x as i64 * 3 - 1).collect();
            par == ser
        },
    );
}

#[test]
fn prop_quantize_encode_decode_identity() {
    // The L3 pipeline's core invariant: for any weights and any sane (Δ, λ),
    // encode(quantize(w)) decodes to exactly the quantized ints.
    use deepcabac::cabac;
    check_slice(
        Config {
            cases: 50,
            seed: 0xF7,
        },
        gen::weights,
        |w| {
            if w.is_empty() {
                return true;
            }
            let max_abs = w.iter().fold(0f32, |m, &x| m.max(x.abs()));
            if max_abs == 0.0 {
                return true;
            }
            let delta = max_abs / 100.0;
            let p = RdParams::new(delta, delta * delta, 128);
            let ints = rd_quantize_layer(w, &[], &p);
            let coding = CodingConfig::default();
            let bytes = cabac::encode_layer(&ints, coding);
            cabac::decode_layer(&bytes, ints.len(), coding)
                .map(|d| d == ints)
                .unwrap_or(false)
        },
    );
}
