#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! End-to-end pins for decode-budget propagation (ISSUE 10 satellite):
//! a [`DecodeLimits`] set at any of the three public entry points —
//! [`ContainerPolicy::builder`], [`DecodeArena`], or
//! [`StoreConfig::limits`] — must actually reach the header walk that
//! enforces it.  Each test drives the committed 2-layer `golden_v3.dcb`
//! fixture through one path twice: once under a budget tightened along a
//! single axis (must fail `Error::Limit`), once under the default budget
//! (must decode).  A budget that silently fails to propagate shows up
//! here as the tight run succeeding.

use std::path::PathBuf;

use deepcabac::api::{ModelStore, StoreConfig};
use deepcabac::model::{
    decode_network_into, CompressedNetwork, ContainerPolicy, DecodeArena, DecodeLimits,
};
use deepcabac::util::Error;

fn fixture(name: &str) -> Vec<u8> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/golden")
        .join(name);
    std::fs::read(&path).unwrap_or_else(|e| panic!("missing fixture {path:?}: {e}"))
}

/// Default budget with one axis pinched shut.
fn tight(axis: &str) -> DecodeLimits {
    let mut l = DecodeLimits::default();
    match axis {
        "layers" => l.max_layers = 1,
        "slices" => l.max_slices = 1,
        "symbols" => l.max_symbols = 1,
        "payload" => l.max_payload_bytes = 1,
        "arena" => l.max_arena_bytes = 16,
        other => panic!("unknown axis {other}"),
    }
    l
}

const AXES: [&str; 5] = ["layers", "slices", "symbols", "payload", "arena"];

#[test]
fn builder_carries_limits_into_policy() {
    let l = tight("layers");
    let p = ContainerPolicy::builder().v3().limits(l).build();
    assert_eq!(p.limits, l, "builder must thread limits through build()");
    assert_eq!(
        ContainerPolicy::default().limits,
        DecodeLimits::default(),
        "default policy carries the default budget"
    );
}

#[test]
fn two_pass_decode_honors_explicit_limits() {
    let raw = fixture("golden_v3.dcb");
    for axis in AXES {
        match CompressedNetwork::from_bytes_with_limits(&raw, 1, tight(axis)) {
            Err(Error::Limit(_)) => {}
            other => panic!(
                "tight {axis} budget must refuse the fixture, got {}",
                match other {
                    Ok(_) => "Ok".into(),
                    Err(e) => format!("{e}"),
                }
            ),
        }
    }
    // Control: the same bytes decode under the default budget.
    let comp = CompressedNetwork::from_bytes_with_limits(&raw, 1, DecodeLimits::default())
        .expect("default budget admits the fixture");
    assert_eq!(comp.layers.len(), 2);
}

#[test]
fn arena_decode_honors_with_limits_and_set_limits() {
    let raw = fixture("golden_v3.dcb");
    for axis in AXES {
        let l = tight(axis);
        let mut arena = DecodeArena::with_limits(l);
        assert_eq!(arena.limits(), l, "with_limits must stick");
        match decode_network_into(&raw, 1, &mut arena) {
            Err(Error::Limit(_)) => {}
            Ok(_) => panic!("tight {axis} budget must refuse the fused decode"),
            Err(e) => panic!("tight {axis}: wanted Error::Limit, got {e}"),
        }
    }
    // set_limits after construction, and re-tightening a *warm* arena:
    // the budget is enforced on every prepare, not just the cold parse.
    let mut arena = DecodeArena::new();
    let n = decode_network_into(&raw, 1, &mut arena)
        .expect("default budget admits the fixture")
        .layers
        .len();
    assert_eq!(n, 2);
    arena.set_limits(tight("symbols"));
    match decode_network_into(&raw, 1, &mut arena) {
        Err(Error::Limit(_)) => {}
        Ok(_) => panic!("warm arena must re-enforce a tightened budget"),
        Err(e) => panic!("warm arena: wanted Error::Limit, got {e}"),
    }
    arena.set_limits(DecodeLimits::default());
    assert!(
        decode_network_into(&raw, 1, &mut arena).is_ok(),
        "restoring the default budget restores service"
    );
}

#[test]
fn store_decode_honors_store_config_limits() {
    let raw = fixture("golden_v3.dcb");
    for axis in AXES {
        let store = ModelStore::new(StoreConfig {
            limits: tight(axis),
            ..StoreConfig::default()
        });
        // Registration validates against the *default* budget by design —
        // a model can be resident yet refused at decode time.
        store
            .register("m", raw.clone())
            .expect("registration uses the default budget");
        match store.decode("m", |net| net.layers.len()) {
            Err(Error::Limit(_)) => {}
            Ok(_) => panic!("store with tight {axis} budget must refuse decode"),
            Err(e) => panic!("store tight {axis}: wanted Error::Limit, got {e}"),
        }
    }
    // Control: a default-budget store serves the same bytes.
    let store = ModelStore::new(StoreConfig::default());
    store.register("m", raw).expect("register");
    assert_eq!(store.decode("m", |net| net.layers.len()).expect("decode"), 2);
}
