#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Integration: the Rust PJRT runtime must reproduce the Python-side golden
//! logits from the AOT eval graphs, and the Pallas rd_assign kernel (via
//! PJRT) must agree with the Rust RDOQ argmin on identical inputs.
//!
//! These tests require `make artifacts`; they are skipped (not failed) when
//! the artifacts directory is absent so `cargo test` works pre-build.

use std::path::PathBuf;

use deepcabac::cabac::context::{CodingConfig, WeightContexts};
use deepcabac::cabac::estimator::CostTable;
use deepcabac::data::Dataset;
use deepcabac::model::read_nwf;
use deepcabac::quant::rd::argmin_rd;
use deepcabac::runtime::{Engine, Evaluator, EVAL_BATCH, KERNEL_HALF, KERNEL_K};
use deepcabac::util::Pcg64;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("MANIFEST.txt").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn read_golden(path: &PathBuf) -> Vec<f32> {
    std::fs::read(path)
        .unwrap()
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

#[test]
fn eval_graphs_reproduce_golden_logits() {
    let Some(art) = artifacts() else { return };
    let engine = Engine::new(&art).unwrap();
    let data = Dataset::load(art.join("dataset.nds")).unwrap();
    for model in ["lenet300", "lenet5", "smallvgg", "mobilenet"] {
        let net = read_nwf(art.join(format!("{model}.nwf"))).unwrap();
        let mats: Vec<(&[f32], usize, usize)> = net
            .layers
            .iter()
            .map(|l| (l.weights.as_slice(), l.rows, l.cols))
            .collect();
        let biases: Vec<&[f32]> = net
            .layers
            .iter()
            .map(|l| l.bias.as_deref().unwrap())
            .collect();
        let x = data.batch_images(0, EVAL_BATCH);
        let logits = engine
            .eval_logits(model, &mats, &biases, x, (data.h, data.w, data.c))
            .unwrap();
        let golden = read_golden(&art.join(format!("golden_logits_{model}.bin")));
        assert_eq!(logits.len(), golden.len(), "{model}");
        let mut max_rel = 0f32;
        for (&a, &b) in logits.iter().zip(&golden) {
            let rel = (a - b).abs() / b.abs().max(1e-3);
            max_rel = max_rel.max(rel);
        }
        assert!(max_rel < 1e-3, "{model}: max rel err {max_rel}");
    }
}

#[test]
fn trained_models_hit_reported_accuracy() {
    let Some(art) = artifacts() else { return };
    let engine = Engine::new(&art).unwrap();
    let data = Dataset::load(art.join("dataset.nds")).unwrap();
    let ev = Evaluator::new(engine, data);
    // MANIFEST top1 figures were computed by python; rust must agree.
    let manifest = std::fs::read_to_string(art.join("MANIFEST.txt")).unwrap();
    for model in ["lenet300", "lenet5", "smallvgg", "mobilenet"] {
        let net = read_nwf(art.join(format!("{model}.nwf"))).unwrap();
        let acc = ev.accuracy(&net).unwrap();
        // Parse `"top1": 0.9521` style values for this model block.
        let key = format!("\"{model}\": {{");
        let blk = &manifest[manifest.find(&key).unwrap()..];
        let t = &blk[blk.find("\"top1\":").unwrap() + 7..];
        let reported: f64 = t[..t.find(',').unwrap()].trim().parse().unwrap();
        assert!(
            (acc - reported).abs() < 0.005,
            "{model}: rust {acc} vs python {reported}"
        );
        assert!(acc > 0.90, "{model} accuracy {acc}");
    }
}

#[test]
fn pallas_kernel_matches_rust_rdoq() {
    let Some(art) = artifacts() else { return };
    let engine = Engine::new(&art).unwrap();
    let mut rng = Pcg64::new(777);
    let n = 20_000; // exercises full chunks + padded tail
    let w: Vec<f32> = rng.normal_vec(n, 0.08);
    let fim: Vec<f32> = (0..n).map(|_| rng.uniform(0.01, 5.0) as f32).collect();
    let ctxs = WeightContexts::new(CodingConfig::default());
    let table = CostTable::build(&ctxs, 0, KERNEL_HALF);
    assert_eq!(table.len(), KERNEL_K);
    let (delta, lambda) = (0.004f32, 0.015f32);
    let device = engine
        .rd_assign(&w, &fim, delta, lambda, &table.cost)
        .unwrap();
    for i in 0..n {
        let host = argmin_rd(w[i], fim[i], delta, lambda, &table);
        assert_eq!(device[i], host, "weight {i}: w={} fim={}", w[i], fim[i]);
    }
}

#[test]
fn dequant_kernel_matches_host() {
    let Some(art) = artifacts() else { return };
    let engine = Engine::new(&art).unwrap();
    let mut rng = Pcg64::new(778);
    let idx: Vec<i32> = (0..deepcabac::runtime::KERNEL_N)
        .map(|_| rng.below(1025) as i32 - 512)
        .collect();
    let delta = 0.0137f32;
    let out = engine.dequant_chunk(&idx, delta).unwrap();
    for (&i, &q) in idx.iter().zip(&out) {
        assert_eq!(q, i as f32 * delta);
    }
}

#[test]
fn quantized_network_keeps_accuracy_at_fine_grid() {
    // End-to-end lossy sanity: 8-bit-ish uniform quantization must not move
    // top-1 by more than half a point (the paper's working regime).
    let Some(art) = artifacts() else { return };
    let engine = Engine::new(&art).unwrap();
    let data = Dataset::load(art.join("dataset.nds")).unwrap();
    let ev = Evaluator::new(engine, data);
    let net = read_nwf(art.join("lenet300.nwf")).unwrap();
    let base = ev.accuracy(&net).unwrap();
    let q = deepcabac::quant::uniform::quantize_network(&net, 255);
    let recon = deepcabac::model::CompressedNetwork {
        name: "lenet300".into(),
        cfg: CodingConfig::default(),
        layers: q,
    }
    .reconstruct_named();
    let qacc = ev.accuracy(&recon).unwrap();
    assert!(
        (base - qacc).abs() < 0.005,
        "8-bit uniform moved accuracy {base} -> {qacc}"
    );
}
