#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Fault-injection property suite for the hardened decode path.
//!
//! The contract under test: feeding **any** corrupted container to the
//! decode entry points produces either a correct decode or a typed
//! [`Error`] — never a panic escape, never allocation beyond the arena
//! budget, never unbounded work.  Three layers of attack:
//!
//! * an **exhaustive single-byte sweep** over all five golden fixtures
//!   (`golden_v1/v2/v3/v4_base/v4.dcb`), each flipped byte tried both
//!   as-is (the CRC gate's job) and CRC-restamped (penetrating to the
//!   header/payload validation behind the gate);
//! * a **seeded mutation engine** ([`deepcabac::testutil::fuzz`]) drawing
//!   bit flips, truncations, splices, length-field inflation and header
//!   corruption over the fixtures plus fresh encodes — `DCB_FUZZ_ITERS`
//!   scales the iteration count (CI's fault-smoke step pins it);
//! * a **counting allocator** asserting every attempt stays far below the
//!   [`DecodeLimits`] arena budget — a length-field inflation that slipped
//!   past validation would show up here as a multi-gigabyte allocation.
//!
//! Debug builds stride-sample the big fixtures to keep `cargo test`
//! snappy; release builds (CI fault-smoke, `--release`) sweep every byte.

use std::alloc::{GlobalAlloc, Layout, System};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use deepcabac::cabac::CodingConfig;
use deepcabac::model::{
    apply_delta_network_into, decode_network_into, CompressedNetwork, ContainerPolicy,
    DecodeArena, DecodeLimits, Kind, QuantizedLayer,
};
use deepcabac::testutil::fuzz::{flip_bit, restamp, Mutator};
use deepcabac::util::Pcg64;

struct CountingAlloc;

static ALLOC_BYTES: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_BYTES.fetch_add(new_size, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Per-attempt allocation ceiling.  Legitimate decodes of the test corpus
/// allocate a few tens of KB; an inflation attack that slipped past the
/// budget checks would claim gigabytes.  The gap leaves room for
/// allocator cross-talk from concurrently running tests in this binary.
const ALLOC_CAP_BYTES: usize = 128 << 20;

/// Tight-but-sufficient budgets for the corpus: every pristine container
/// here fits comfortably, every advertised-size attack is refused long
/// before [`ALLOC_CAP_BYTES`].
fn limits() -> DecodeLimits {
    DecodeLimits {
        max_layers: 1 << 10,
        max_slices: 1 << 16,
        max_symbols: 1 << 22,
        max_payload_bytes: 1 << 24,
        max_arena_bytes: 64 << 20,
    }
}

fn fixture(name: &str) -> Vec<u8> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/golden")
        .join(name);
    std::fs::read(&path).unwrap_or_else(|e| panic!("missing fixture {path:?}: {e}"))
}

/// Debug builds sample every 7th byte; release sweeps exhaustively.
fn sweep_stride() -> usize {
    if cfg!(debug_assertions) {
        7
    } else {
        1
    }
}

/// One contained decode attempt: must return (never unwind) and stay
/// under the allocation cap.  The `Result` itself is unconstrained — a
/// mutation the format cannot distinguish from a valid stream decoding
/// successfully is fine; a panic escape or allocation blow-up is not.
fn attempt_full(arena: &mut DecodeArena, raw: &[u8], threads: usize, label: &str) {
    let before = ALLOC_BYTES.load(Ordering::Relaxed);
    let r = catch_unwind(AssertUnwindSafe(|| {
        decode_network_into(raw, threads, arena).map(|n| n.param_count())
    }));
    let spent = ALLOC_BYTES.load(Ordering::Relaxed).wrapping_sub(before);
    assert!(r.is_ok(), "panic escaped the hardened decode path: {label}");
    assert!(
        spent < ALLOC_CAP_BYTES,
        "{label}: decode allocated {spent} bytes (cap {ALLOC_CAP_BYTES})"
    );
}

/// Same contract for the fused v4 apply path.
fn attempt_apply(arena: &mut DecodeArena, base: &[u8], delta: &[u8], label: &str) {
    let before = ALLOC_BYTES.load(Ordering::Relaxed);
    let r = catch_unwind(AssertUnwindSafe(|| {
        apply_delta_network_into(base, delta, 1, arena).map(|n| n.param_count())
    }));
    let spent = ALLOC_BYTES.load(Ordering::Relaxed).wrapping_sub(before);
    assert!(r.is_ok(), "panic escaped the hardened apply path: {label}");
    assert!(
        spent < ALLOC_CAP_BYTES,
        "{label}: apply allocated {spent} bytes (cap {ALLOC_CAP_BYTES})"
    );
}

/// And for the two-pass (`from_bytes`) decode, which exercises
/// `parse_container` rather than the arena walker.
fn attempt_two_pass(raw: &[u8], label: &str) {
    let before = ALLOC_BYTES.load(Ordering::Relaxed);
    let r = catch_unwind(AssertUnwindSafe(|| {
        CompressedNetwork::from_bytes_with_limits(raw, 1, limits()).map(|c| c.param_count())
    }));
    let spent = ALLOC_BYTES.load(Ordering::Relaxed).wrapping_sub(before);
    assert!(r.is_ok(), "panic escaped the two-pass decode path: {label}");
    assert!(
        spent < ALLOC_CAP_BYTES,
        "{label}: decode allocated {spent} bytes (cap {ALLOC_CAP_BYTES})"
    );
}

#[test]
fn exhaustive_single_byte_flips_never_escape_typed_errors() {
    let mut arena = DecodeArena::with_limits(limits());
    for file in [
        "golden_v1.dcb",
        "golden_v2.dcb",
        "golden_v3.dcb",
        "golden_v4_base.dcb",
    ] {
        let raw = fixture(file);
        for i in (0..raw.len()).step_by(sweep_stride()) {
            // whole-byte flip, stale CRC: the outer gate's territory
            let mut m = raw.clone();
            m[i] ^= 0xFF;
            attempt_full(&mut arena, &m, 1, &format!("{file} byte {i}"));
            // restamped: the mutation penetrates to header/payload checks
            restamp(&mut m);
            attempt_full(&mut arena, &m, 1, &format!("{file} byte {i} restamped"));
            // single-bit flip, restamped: the subtlest corruption class
            let mut b = raw.clone();
            flip_bit(&mut b, i, (i % 8) as u32);
            restamp(&mut b);
            attempt_full(&mut arena, &b, 1, &format!("{file} bit {i}.{}", i % 8));
        }
    }
}

#[test]
fn exhaustive_delta_byte_flips_never_escape_typed_errors() {
    let base = fixture("golden_v4_base.dcb");
    let delta = fixture("golden_v4.dcb");
    let mut arena = DecodeArena::with_limits(limits());
    // The delta fixture is small — always sweep it exhaustively, through
    // the fused apply path (skip table, residual planes, base linkage).
    for i in 0..delta.len() {
        let mut m = delta.clone();
        m[i] ^= 0xFF;
        attempt_apply(&mut arena, &base, &m, &format!("golden_v4 byte {i}"));
        restamp(&mut m);
        attempt_apply(&mut arena, &base, &m, &format!("golden_v4 byte {i} restamped"));
        let mut b = delta.clone();
        flip_bit(&mut b, i, (i % 8) as u32);
        restamp(&mut b);
        attempt_apply(&mut arena, &base, &b, &format!("golden_v4 bit {i}.{}", i % 8));
    }
    // A corrupted *base* under a pristine delta must also fail typed (the
    // base-CRC pin), never panic.
    for i in (0..base.len()).step_by(sweep_stride()) {
        let mut m = base.clone();
        m[i] ^= 0xFF;
        attempt_apply(&mut arena, &m, &delta, &format!("v4_base byte {i}"));
        restamp(&mut m);
        attempt_apply(&mut arena, &m, &delta, &format!("v4_base byte {i} restamped"));
    }
}

/// Fresh encodes widen the corpus beyond the fixtures' fixed geometry:
/// multiple versions, slice lengths, magnitudes and bias layouts.
fn fresh_corpus() -> Vec<Vec<u8>> {
    let mut rng = Pcg64::new(0xC0FFEE);
    let mut make = |name: &str, rows: usize, cols: usize, mag: u64| {
        let ints = (0..rows * cols)
            .map(|_| {
                if rng.below(10) < 6 {
                    0
                } else {
                    let m = rng.below(mag) as i32 + 1;
                    if rng.below(2) == 1 {
                        -m
                    } else {
                        m
                    }
                }
            })
            .collect();
        CompressedNetwork {
            name: name.into(),
            cfg: CodingConfig::default(),
            layers: vec![QuantizedLayer {
                name: "l0".into(),
                kind: Kind::Dense,
                shape: vec![cols, rows],
                rows,
                cols,
                ints,
                delta: 0.01,
                bias: Some((0..rows).map(|r| r as f32 * 0.25).collect()),
            }],
        }
    };
    vec![
        make("f1", 20, 30, 9).to_bytes_with(ContainerPolicy {
            threads: 1,
            ..ContainerPolicy::v1()
        }),
        make("f2", 16, 40, 200).to_bytes_with(ContainerPolicy::v2(64, 1)),
        make("f3", 24, 24, 40_000).to_bytes_with(ContainerPolicy::v3(64, 1)),
        make("f4", 32, 32, 5).to_bytes_with(ContainerPolicy::v3(4096, 1)),
    ]
}

#[test]
fn seeded_fuzzer_mutations_never_escape_typed_errors() {
    let iters: usize = std::env::var("DCB_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if cfg!(debug_assertions) { 128 } else { 1024 });
    let mut corpus = vec![
        fixture("golden_v1.dcb"),
        fixture("golden_v2.dcb"),
        fixture("golden_v3.dcb"),
        fixture("golden_v4_base.dcb"),
    ];
    corpus.extend(fresh_corpus());
    let base = fixture("golden_v4_base.dcb");
    let delta = fixture("golden_v4.dcb");

    let mut mutator = Mutator::new(0xFA57_F00D);
    let mut arena = DecodeArena::with_limits(limits());
    for it in 0..iters {
        let src = &corpus[it % corpus.len()];
        let (m, rep) = mutator.mutate(src);
        // Rotate threads so both the sequential and the grouped
        // (interleaved) slice schedules face every mutation class.
        let threads = if it % 3 == 0 { 4 } else { 1 };
        let label = format!("iter {it} {rep:?}");
        attempt_full(&mut arena, &m, threads, &label);
        attempt_two_pass(&m, &label);
        // Every few iterations, mutate the delta and drive the apply path.
        if it % 5 == 0 {
            let (dm, drep) = mutator.mutate(&delta);
            attempt_apply(&mut arena, &base, &dm, &format!("iter {it} {drep:?}"));
        }
    }

    // The arena that absorbed the whole campaign still decodes pristine
    // streams correctly — refusals must not wedge serving state.
    let good = fixture("golden_v3.dcb");
    let expect = CompressedNetwork::from_bytes(&good).unwrap().param_count();
    let got = decode_network_into(&good, 1, &mut arena)
        .expect("pristine decode after fuzz campaign")
        .param_count();
    assert_eq!(got, expect);
}
