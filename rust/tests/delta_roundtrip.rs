#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Cross-version container identity and delta-application properties.
//!
//! Two families of guarantees ride here:
//!
//! * **Refactor identity** — every full-container version (v1/v2/v3) now
//!   routes its wire decisions through [`ContainerFormat`]; these tests
//!   pin that encode→decode→re-encode is byte-identical per version and
//!   that all versions decode to the same network with the same
//!   version-agnostic shape key (absolute bytes are pinned separately by
//!   the golden-vector suite).
//! * **Delta equivalence** — for *every* base version pairing, applying a
//!   DCB4 delta through the fused arena path equals the eager
//!   `base + residual·Δ` reconstruction bit for bit, and equals the
//!   eagerly-updated network the delta was diffed from.

use deepcabac::coordinator::{diff_network, patch_network};
use deepcabac::model::{
    apply_delta_network_into, container_shape_key, probe, CompressedNetwork, ContainerFormat,
    ContainerPolicy, DecodeArena, Kind, Network, QuantizedLayer, VERSION_V1, VERSION_V2,
    VERSION_V3, VERSION_V4,
};
use deepcabac::util::{Error, Pcg64};

const SLICE_LEN: usize = 64;

/// Three-layer synthetic network: mixed kinds, mixed bias presence,
/// sparse integer planes — enough structure to exercise slice framing
/// and the skip table without being slow under the legacy v1 bins.
fn synth_network(seed: u64) -> CompressedNetwork {
    let mut rng = Pcg64::new(seed);
    let mut mk = |name: &str, kind: Kind, rows: usize, cols: usize, biased: bool| {
        let ints = (0..rows * cols)
            .map(|_| {
                if rng.next_f64() < 0.6 {
                    0
                } else {
                    rng.below(31) as i32 - 15
                }
            })
            .collect();
        QuantizedLayer {
            name: name.into(),
            kind,
            shape: vec![cols, rows],
            rows,
            cols,
            ints,
            delta: 0.02,
            bias: biased.then(|| rng.normal_vec(rows, 0.05)),
        }
    };
    CompressedNetwork {
        name: "xver".into(),
        cfg: Default::default(),
        layers: vec![
            mk("conv0", Kind::Conv, 12, 27, true),
            mk("fc1", Kind::Dense, 20, 18, true),
            mk("head", Kind::Dense, 6, 20, false),
        ],
    }
}

fn versions() -> [(u8, ContainerPolicy); 3] {
    [
        (VERSION_V1, ContainerPolicy::v1()),
        (VERSION_V2, ContainerPolicy::v2(SLICE_LEN, 2)),
        (VERSION_V3, ContainerPolicy::v3(SLICE_LEN, 2)),
    ]
}

/// On-grid perturbation of ~10% of one layer's weights, in residual
/// steps of `delta` — reproducible exactly by RDOQ at near-zero λ.
fn perturb(net: &mut Network, layer: usize, delta: f32, seed: u64) {
    let mut rng = Pcg64::new(seed);
    for w in net.layers[layer].weights.iter_mut() {
        if rng.next_f64() < 0.1 {
            let k = rng.below(5) as i32 - 2;
            *w += k as f32 * delta;
        }
    }
}

fn bits(net: &Network) -> Vec<Vec<u32>> {
    net.layers
        .iter()
        .map(|l| l.weights.iter().map(|w| w.to_bits()).collect())
        .collect()
}

#[test]
fn every_version_reencodes_byte_identical_through_container_format() {
    let net = synth_network(71);
    for (version, policy) in versions() {
        let raw = net.to_bytes_with(policy);
        let header = probe(&raw).unwrap();
        assert_eq!(header.version, version);
        assert!(header.delta.is_none());
        // the dispatch object agrees with what landed on the wire
        let fmt = ContainerFormat::from_version(version).unwrap();
        assert_eq!(fmt.version(), version);
        assert!(!fmt.is_delta());
        assert_eq!(fmt.sliced(), version != VERSION_V1);
        assert_eq!(fmt.legacy_bins(), version != VERSION_V3);
        for threads in [1usize, 4] {
            let got = CompressedNetwork::from_bytes_with(&raw, threads).unwrap();
            assert_eq!(got.name, net.name, "v{version}");
            assert_eq!(got.layers, net.layers, "v{version} threads={threads}");
        }
        assert_eq!(net.to_bytes_with(policy), raw, "v{version} re-encode drifted");
    }
}

#[test]
fn all_versions_share_one_shape_key() {
    let net = synth_network(72);
    let keys: Vec<u64> = versions()
        .iter()
        .map(|(_, p)| container_shape_key(&net.to_bytes_with(*p)).unwrap())
        .collect();
    assert_eq!(keys[0], keys[1]);
    assert_eq!(keys[1], keys[2], "shape key must ignore the version byte");

    // Δ is excluded too: a re-quantized sibling stays delta-compatible…
    let mut requant = net.clone();
    for l in requant.layers.iter_mut() {
        l.delta *= 0.5;
    }
    let requant_key =
        container_shape_key(&requant.to_bytes_with(ContainerPolicy::v3(SLICE_LEN, 2))).unwrap();
    assert_eq!(requant_key, keys[0]);

    // …but geometry is not: a renamed layer breaks the key.
    let mut renamed = net.clone();
    renamed.layers[1].name = "fc1b".into();
    let renamed_key =
        container_shape_key(&renamed.to_bytes_with(ContainerPolicy::v3(SLICE_LEN, 2))).unwrap();
    assert_ne!(renamed_key, keys[0]);
}

#[test]
fn delta_apply_matches_eager_for_every_base_version() {
    let net = synth_network(73);
    let step = 0.005f32;
    for (version, policy) in versions() {
        let base_raw = net.to_bytes_with(policy);
        let mut updated = net.reconstruct_named();
        perturb(&mut updated, 0, step, 90 + version as u64);
        perturb(&mut updated, 2, step, 91 + version as u64);

        let d = diff_network(&base_raw, &updated, step, 0.01, ContainerPolicy::v3(SLICE_LEN, 2))
            .unwrap();
        let delta_raw = d.to_bytes_with(ContainerPolicy::v3(SLICE_LEN, 2));
        assert_eq!(probe(&delta_raw).unwrap().version, VERSION_V4);
        assert!(d.layers[1].skipped(), "v{version}: untouched layer must skip");

        let eager = d.apply_to(&net.reconstruct_named()).unwrap();
        let expect = bits(&updated);
        assert_eq!(bits(&eager), expect, "v{version}: eager apply != eager update");
        let mut arena = DecodeArena::new();
        for threads in [1usize, 4] {
            let fused =
                apply_delta_network_into(&base_raw, &delta_raw, threads, &mut arena).unwrap();
            assert_eq!(
                bits(fused),
                expect,
                "v{version} threads={threads}: fused apply != eager update"
            );
            for (f, u) in fused.layers.iter().zip(&updated.layers) {
                assert_eq!(f.bias, u.bias, "v{version}");
            }
        }
        // the convenience wrapper rides the same path
        let patched = patch_network(&base_raw, &delta_raw, 2).unwrap();
        assert_eq!(bits(&patched), expect, "v{version}");
    }
}

#[test]
fn deltas_pin_exact_base_bytes_not_just_geometry() {
    // A delta diffed against the v1 serialization must refuse the v2/v3
    // serializations of the *same network*: shape keys match, content
    // CRCs do not — and the CRC gate fires first.
    let net = synth_network(74);
    let v1_raw = net.to_bytes_with(ContainerPolicy::v1());
    let mut updated = net.reconstruct_named();
    perturb(&mut updated, 1, 0.005, 95);
    let d =
        diff_network(&v1_raw, &updated, 0.005, 0.01, ContainerPolicy::v3(SLICE_LEN, 2)).unwrap();
    let delta_raw = d.to_bytes_with(ContainerPolicy::v3(SLICE_LEN, 2));
    for policy in [
        ContainerPolicy::v2(SLICE_LEN, 2),
        ContainerPolicy::v3(SLICE_LEN, 2),
    ] {
        let other_raw = net.to_bytes_with(policy);
        assert_eq!(
            container_shape_key(&other_raw).unwrap(),
            d.base_shape_key,
            "same network ⇒ same shape key regardless of version"
        );
        let mut arena = DecodeArena::new();
        let err = apply_delta_network_into(&other_raw, &delta_raw, 2, &mut arena).unwrap_err();
        assert!(matches!(err, Error::Crc(_)), "{err}");
        // the refusal names both sides: the CRC the delta pinned (the v1
        // bytes) and what the offered serialization hashes to
        let msg = err.to_string();
        let pinned = format!("{:08x}", d.base_crc32);
        let offered = format!("{:08x}", deepcabac::util::crc32(&other_raw));
        assert!(msg.contains(&pinned), "missing pinned crc {pinned}: {msg}");
        assert!(msg.contains(&offered), "missing offered crc {offered}: {msg}");
    }
}

#[test]
fn skip_flags_on_the_wire_match_the_unchanged_layers() {
    let net = synth_network(75);
    let base_raw = net.to_bytes_with(ContainerPolicy::v3(SLICE_LEN, 2));
    let mut updated = net.reconstruct_named();
    perturb(&mut updated, 1, 0.005, 96);
    let d =
        diff_network(&base_raw, &updated, 0.005, 0.01, ContainerPolicy::v3(SLICE_LEN, 2)).unwrap();
    let delta_raw = d.to_bytes_with(ContainerPolicy::v3(SLICE_LEN, 2));

    let expected_skips = vec![true, false, true];
    assert_eq!(
        d.layers.iter().map(|l| l.skipped()).collect::<Vec<_>>(),
        expected_skips
    );
    let header = probe(&delta_raw).unwrap();
    assert_eq!(
        header.layers.iter().map(|l| l.skipped).collect::<Vec<_>>(),
        expected_skips,
        "probe must report the wire skip table, not re-derive it"
    );
    for l in header.layers.iter().filter(|l| l.skipped) {
        assert_eq!(l.n_slices, 0);
        assert_eq!(l.payload_bytes, 0);
    }
    assert!(
        delta_raw.len() * 2 < base_raw.len(),
        "one perturbed layer out of three should compress far below full ({} vs {})",
        delta_raw.len(),
        base_raw.len()
    );
}
