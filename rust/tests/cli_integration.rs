#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! CLI integration: exercise the `deepcabac` binary end to end through
//! std::process (compress → info → decompress → eval), the UX a downstream
//! user actually touches.  Skipped when artifacts are absent.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> PathBuf {
    // target/<profile>/deepcabac next to the test executable
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // profile dir
    p.join("deepcabac")
}

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("MANIFEST.txt").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn no_args_prints_usage() {
    let (ok, _, err) = run(&[]);
    assert!(!ok);
    assert!(err.contains("usage"));
}

#[test]
fn unknown_verb_fails() {
    let (ok, _, err) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(err.contains("usage"));
}

#[test]
fn missing_file_is_clean_error() {
    let (ok, _, err) = run(&["info", "/nonexistent/model.nwf"]);
    assert!(!ok);
    assert!(err.contains("error:"));
}

#[test]
fn compress_info_decompress_eval_roundtrip() {
    let Some(art) = artifacts() else { return };
    let dir = std::env::temp_dir().join("dcb_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let dcb = dir.join("m.dcb");
    let nwf_out = dir.join("m_back.nwf");

    let (ok, out, err) = run(&[
        "compress",
        art.join("lenet5.nwf").to_str().unwrap(),
        "-o",
        dcb.to_str().unwrap(),
        "--delta",
        "0.01",
        "--lambda",
        "1.0",
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("% of original"), "{out}");

    let (ok, out, _) = run(&["info", dcb.to_str().unwrap()]);
    assert!(ok);
    // compress defaults to the sliced bypass-fast-path v3 container; info
    // reports the version and per-layer slice structure
    assert!(out.contains("dcb v3"), "{out}");
    assert!(out.contains("slices="), "{out}");
    assert!(out.contains("conv1"));

    // legacy v1 container still round-trips through the same verbs
    let dcb1 = dir.join("m_v1.dcb");
    let (ok, _, err) = run(&[
        "compress",
        art.join("lenet5.nwf").to_str().unwrap(),
        "-o",
        dcb1.to_str().unwrap(),
        "--container",
        "v1",
        "--delta",
        "0.01",
        "--lambda",
        "1.0",
    ]);
    assert!(ok, "{err}");
    let (ok, out, _) = run(&["info", dcb1.to_str().unwrap()]);
    assert!(ok);
    assert!(out.contains("dcb v1"), "{out}");

    let (ok, out, err) = run(&[
        "decompress",
        dcb.to_str().unwrap(),
        "-o",
        nwf_out.to_str().unwrap(),
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("4 layers"));

    // Decompressed .nwf loads and matches the dcb's dequantized weights.
    let net = deepcabac::model::read_nwf(&nwf_out).unwrap();
    let raw = std::fs::read(&dcb).unwrap();
    let comp = deepcabac::model::CompressedNetwork::from_bytes(&raw).unwrap();
    for (l, q) in net.layers.iter().zip(&comp.layers) {
        assert_eq!(l.weights, q.dequantize());
    }

    let (ok, out, err) = run(&[
        "eval",
        dcb.to_str().unwrap(),
        "--artifacts",
        art.to_str().unwrap(),
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("top-1"), "{out}");
}

#[test]
fn eval_original_model() {
    let Some(art) = artifacts() else { return };
    let (ok, out, err) = run(&[
        "eval",
        art.join("lenet300.nwf").to_str().unwrap(),
        "--artifacts",
        art.to_str().unwrap(),
    ]);
    assert!(ok, "{err}");
    // lenet300 trained to ~95%
    let pct: f64 = out
        .split("= ")
        .nth(1)
        .and_then(|s| s.trim_end().trim_end_matches('%').parse().ok())
        .unwrap_or(0.0);
    assert!(pct > 90.0, "{out}");
}
