#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! ModelStore serving-layer contract suite — deterministic, loom-free.
//!
//! Pins the behaviors the serving layer promises: LRU arena eviction in
//! recency order, warm-arena sharing across same-shape models, fail-fast
//! backpressure at the admission bound, registration-time container
//! validation, and the poisoning-impossible panic story (a panicking
//! request forfeits only its checked-out arena and releases its
//! admission slot).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Barrier;

use deepcabac::api::{AdmissionPolicy, ModelStore, StoreConfig};
use deepcabac::cabac::CodingConfig;
use deepcabac::model::{CompressedNetwork, ContainerPolicy, Kind, QuantizedLayer};
use deepcabac::util::Pcg64;
use deepcabac::Error;

/// One-layer `.dcb` container.  The embedded network name participates in
/// the arena shape key, so same-`name` same-dims containers share warmed
/// arenas while differing in payload (seeded rng).
fn container(name: &str, rows: usize, cols: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg64::new(seed);
    let ints = (0..rows * cols)
        .map(|_| {
            if rng.next_f64() < 0.7 {
                0
            } else {
                rng.below(41) as i32 - 20
            }
        })
        .collect();
    let net = CompressedNetwork {
        name: name.into(),
        cfg: CodingConfig::default(),
        layers: vec![QuantizedLayer {
            name: "fc".into(),
            kind: Kind::Dense,
            shape: vec![cols, rows],
            rows,
            cols,
            ints,
            delta: 0.01,
            bias: None,
        }],
    };
    net.to_bytes_with(ContainerPolicy::v3(512, 1))
}

#[test]
fn lru_eviction_follows_recency_order() {
    let store = ModelStore::new(StoreConfig {
        arena_capacity: 2,
        ..StoreConfig::default()
    });
    let a = store.register("a", container("a", 6, 8, 1)).unwrap();
    let b = store.register("b", container("b", 7, 9, 2)).unwrap();
    let c = store.register("c", container("c", 9, 11, 3)).unwrap();
    assert_ne!(a.shape_key, b.shape_key);
    assert_ne!(b.shape_key, c.shape_key);
    assert_ne!(a.shape_key, c.shape_key);

    store.decode("a", |_| ()).unwrap();
    store.decode("b", |_| ()).unwrap();
    assert_eq!(store.arena_keys_by_recency(), vec![a.shape_key, b.shape_key]);
    // Re-serving "a" refreshes its arena's recency...
    store.decode("a", |_| ()).unwrap();
    assert_eq!(store.arena_keys_by_recency(), vec![b.shape_key, a.shape_key]);
    // ...so "b"'s arena is the LRU victim when "c" needs a slot.
    store.decode("c", |_| ()).unwrap();
    assert_eq!(store.arena_keys_by_recency(), vec![a.shape_key, c.shape_key]);
    let st = store.stats();
    assert_eq!(st.requests, 4);
    assert_eq!(st.arena_misses, 3);
    assert_eq!(st.arena_hits, 1);
    assert_eq!(st.evictions, 1);
}

#[test]
fn same_shape_models_share_warm_arenas() {
    let store = ModelStore::new(StoreConfig::default());
    let a = store.register("alpha", container("twin", 10, 12, 7)).unwrap();
    let b = store.register("beta", container("twin", 10, 12, 8)).unwrap();
    assert_eq!(a.shape_key, b.shape_key, "same identity, same arena key");
    assert_ne!(a.content_crc32, b.content_crc32, "distinct payloads");

    let wa = store.decode("alpha", |n| n.layers[0].weights.clone()).unwrap();
    let wb = store.decode("beta", |n| n.layers[0].weights.clone()).unwrap();
    assert_ne!(wa, wb, "each model's own planes through the shared arena");
    let st = store.stats();
    assert_eq!(st.arena_misses, 1, "only the first request built an arena");
    assert_eq!(st.arena_hits, 1, "the same-shape sibling reused it warm");
    assert_eq!(store.arena_keys_by_recency(), vec![a.shape_key]);
}

#[test]
fn unregister_drops_the_model_but_keeps_shared_arenas() {
    let store = ModelStore::new(StoreConfig::default());
    store.register("alpha", container("twin", 8, 8, 11)).unwrap();
    store.register("beta", container("twin", 8, 8, 12)).unwrap();
    store.decode("alpha", |_| ()).unwrap();
    assert!(store.unregister("alpha"));
    assert!(!store.unregister("alpha"), "already gone");
    assert_eq!(store.len(), 1);
    // The arena outlives the model that built it: beta hits it warm.
    store.decode("beta", |_| ()).unwrap();
    let st = store.stats();
    assert_eq!(st.arena_misses, 1);
    assert_eq!(st.arena_hits, 1);
}

#[test]
fn register_validates_and_decode_checks_residency() {
    let store = ModelStore::default();
    assert!(store.register("bad", vec![1, 2, 3]).is_err());
    assert!(store.is_empty());
    let err = store.decode("ghost", |_| ()).unwrap_err();
    assert!(matches!(err, Error::Config(_)), "{err:?}");
}

#[test]
fn fail_fast_sheds_requests_at_capacity() {
    let store = ModelStore::new(StoreConfig {
        max_in_flight: 1,
        admission: AdmissionPolicy::FailFast,
        ..StoreConfig::default()
    });
    store.register("m", container("m", 6, 6, 5)).unwrap();
    let inside = Barrier::new(2);
    let release = Barrier::new(2);
    std::thread::scope(|s| {
        let holder = s.spawn(|| {
            store.decode("m", |_| {
                inside.wait();
                release.wait();
            })
        });
        inside.wait();
        // The only admission slot is held inside the closure above.
        let err = store.decode("m", |_| ()).unwrap_err();
        assert!(matches!(err, Error::Backpressure(_)), "{err:?}");
        assert_eq!(store.stats().rejected, 1);
        release.wait();
        holder.join().unwrap().unwrap();
    });
    // Slot released: the store serves again.
    store.decode("m", |_| ()).unwrap();
    assert_eq!(store.stats().rejected, 1);
}

#[test]
fn panicking_request_poisons_nothing() {
    let store = ModelStore::new(StoreConfig {
        max_in_flight: 1,
        admission: AdmissionPolicy::FailFast,
        ..StoreConfig::default()
    });
    let m = store.register("m", container("m", 6, 6, 9)).unwrap();
    store.decode("m", |_| ()).unwrap(); // warm one arena
    assert_eq!(store.arena_keys_by_recency(), vec![m.shape_key]);

    let unwound = catch_unwind(AssertUnwindSafe(|| {
        store.decode("m", |_| panic!("request blew up"))
    }));
    assert!(unwound.is_err(), "the panic reaches the caller");

    // The checked-out arena went down with the panic — forfeited, not
    // poisoned...
    assert!(store.arena_keys_by_recency().is_empty());
    // ...the RAII permit restored the only admission slot (fail-fast
    // would shed otherwise), and the registry still serves.
    store.decode("m", |_| ()).unwrap();
    assert_eq!(store.len(), 1);
    assert_eq!(store.arena_keys_by_recency(), vec![m.shape_key]);
}

#[test]
fn failing_container_quarantines_while_healthy_models_keep_serving() {
    use deepcabac::api::{DecodeLimits, ModelHealth};

    // A symbol budget between the two models' parameter counts makes the
    // big container fail *deterministically* at decode time: registration
    // validates under the default (generous) limits, so the bad model is
    // resident yet refused on every serve attempt.
    let small = container("small", 6, 6, 21); // 36 symbols
    let big = container("big", 40, 40, 22); // 1600 symbols
    let store = ModelStore::new(StoreConfig {
        limits: DecodeLimits {
            max_symbols: 200,
            ..DecodeLimits::default()
        },
        max_failures: 2,
        ..StoreConfig::default()
    });
    store.register("small", small).unwrap();
    store.register("big", big).unwrap();
    assert_eq!(store.health("big"), Some(ModelHealth::Healthy));

    // Two over-budget decodes trip the max_failures=2 threshold...
    for i in 0..2 {
        let err = store.decode("big", |_| ()).unwrap_err();
        assert!(matches!(err, Error::Limit(_)), "attempt {i}: {err}");
        // ...with healthy traffic interleaved and unaffected throughout.
        store.decode("small", |_| ()).unwrap();
    }
    assert_eq!(store.health("big"), Some(ModelHealth::Quarantined));

    // Quarantined requests are refused up front (no decode work), and are
    // accounted separately from decode failures.
    let err = store.decode("big", |_| ()).unwrap_err();
    assert!(matches!(err, Error::Quarantined(_)), "{err}");
    store.decode("small", |_| ()).unwrap();

    let st = store.stats();
    assert_eq!(st.decode_errors, 2);
    assert_eq!(st.quarantine_events, 1);
    assert_eq!(st.quarantine_rejections, 1);

    // Reinstating clears the refusal, but the container is still over
    // budget — the streak restarts at one, below the threshold.
    assert!(store.reinstate("big"));
    assert!(matches!(store.decode("big", |_| ()), Err(Error::Limit(_))));
    assert_eq!(store.health("big"), Some(ModelHealth::Healthy));
}

#[test]
fn expired_deadline_is_typed_counted_and_nonsticky() {
    let store = ModelStore::new(StoreConfig {
        decode_deadline: Some(std::time::Duration::ZERO),
        max_failures: 0, // quarantine disabled: expiries must not quarantine
        ..StoreConfig::default()
    });
    store.register("m", container("m", 12, 12, 31)).unwrap();
    for _ in 0..3 {
        let err = store.decode("m", |_| ()).unwrap_err();
        assert!(matches!(err, Error::Deadline(_)), "{err}");
    }
    let st = store.stats();
    assert_eq!(st.deadline_expiries, 3);
    assert_eq!(st.decode_errors, 3);
    assert_eq!(st.quarantine_events, 0, "max_failures=0 disables quarantine");
    assert_eq!(
        store.health("m"),
        Some(deepcabac::api::ModelHealth::Healthy)
    );
}
