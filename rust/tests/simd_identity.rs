#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Scalar/SIMD identity pins: the `simd` cargo feature must change **no
//! observable bit** anywhere — not one f32 bit pattern in a reconstructed
//! plane, not one chosen RDOQ index, not one byte of an encoded stream.
//!
//! The suite runs in both builds.  Without `--features simd` it pins the
//! scalar kernels against longhand references (so the references
//! themselves are known-good); with `--features simd` the same assertions
//! pin the vector kernels against those references, and CI runs the suite
//! both ways on the same golden fixtures — that cross-build agreement *is*
//! the byte-identity check (the committed fixtures were produced by the
//! scalar build).
//!
//! Inputs deliberately include NaN, ±∞, subnormals, negative zero and
//! magnitude extremes: the kernels' contract is bit-identity on *every*
//! input, not just well-behaved weights.
//!
//! The second half pins the interleaved multi-slice decode schedule:
//! round-robining k slice coders per worker must reproduce the sequential
//! per-slice decode bit-for-bit under randomized slice layouts, container
//! versions, thread counts and interleave widths.

use deepcabac::cabac::{
    build_cost_tables, decode_layer_dequant_sliced_into_interleaved,
    decode_layer_sliced_interleaved, encode_layer_sliced, CodingConfig, WeightContexts,
};
use deepcabac::model::{
    decode_network_into_with, CompressedNetwork, ContainerPolicy, DecodeArena, Kind,
    QuantizedLayer,
};
use deepcabac::quant::rd::{argmin_rd, argmin_rd_window};
use deepcabac::util::parallel::MAX_DECODE_INTERLEAVE;
use deepcabac::util::simd;
use deepcabac::util::Pcg64;

/// Adversarial float pool: every draw has a chance of being a special
/// value, the rest are scale-varied normals.
fn adversarial(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    const SPECIALS: [f32; 10] = [
        0.0,
        -0.0,
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MIN_POSITIVE,
        f32::MIN_POSITIVE / 8.0, // subnormal
        -f32::MIN_POSITIVE / 2.0,
        3.0e38,
        -1.0e-30,
    ];
    (0..n)
        .map(|_| {
            if rng.next_f64() < 0.15 {
                SPECIALS[rng.below(SPECIALS.len() as u64) as usize]
            } else {
                let mag = (rng.next_f64() * 20.0 - 10.0).exp2() as f32;
                if rng.next_f64() < 0.5 {
                    -mag
                } else {
                    mag
                }
            }
        })
        .collect()
}

#[test]
fn dequant_kernel_is_bit_identical_to_scalar_map() {
    let mut rng = Pcg64::new(0x51D0);
    for round in 0..50 {
        let n = 1 + rng.below(300) as usize;
        let syms: Vec<i32> = (0..n)
            .map(|_| rng.below(1 << 20) as i32 - (1 << 19))
            .collect();
        let delta = match round % 5 {
            0 => 0.0,
            1 => -0.125,
            2 => f32::MIN_POSITIVE,
            3 => 1.0e30,
            _ => (rng.next_f64() as f32) * 0.1,
        };
        let mut out = vec![f32::NAN; n];
        simd::dequant_into(&syms, delta, &mut out);
        for (&s, &o) in syms.iter().zip(&out) {
            assert_eq!(o.to_bits(), (s as f32 * delta).to_bits(), "sym={s} delta={delta}");
        }
    }
}

#[test]
fn distortion_sum_is_bit_identical_to_sequential_accumulation() {
    let mut rng = Pcg64::new(0x51D1);
    for _ in 0..40 {
        let n = rng.below(500) as usize;
        let a = adversarial(&mut rng, n);
        let b = adversarial(&mut rng, n);
        let got = simd::squared_error_sum(&a, &b);
        let mut want = 0f64;
        for (&x, &y) in a.iter().zip(&b) {
            let e = (x - y) as f64;
            want += e * e;
        }
        assert_eq!(got.to_bits(), want.to_bits());
    }
}

#[test]
fn importance_div_clamp_is_bit_identical_to_scalar_map() {
    let mut rng = Pcg64::new(0x51D2);
    for _ in 0..40 {
        let n = rng.below(200) as usize;
        let src = adversarial(&mut rng, n);
        let div = if rng.next_f64() < 0.1 {
            0.0
        } else {
            rng.next_f64() as f32 + 0.01
        };
        let out = simd::div_clamp(&src, div, 1e-6, 1e6);
        for (&x, &o) in src.iter().zip(&out) {
            assert_eq!(
                o.to_bits(),
                (x / div).clamp(1e-6, 1e6).to_bits(),
                "x={x} div={div}"
            );
        }
    }
}

/// Longhand full-scan reference for [`argmin_rd`] — the pre-SIMD loop,
/// written out independently of `util::simd`.
fn ref_argmin_rd(w: f32, f: f32, delta: f32, lambda: f32, cost: &[f32], half: i32) -> i32 {
    let mut best = f32::INFINITY;
    let mut best_i = -half;
    for (j, &c) in cost.iter().enumerate() {
        let i = j as i32 - half;
        let d = w - delta * i as f32;
        let total = f * d * d + lambda * c;
        if total < best {
            best = total;
            best_i = i;
        }
    }
    best_i
}

#[test]
fn rdoq_argmins_are_bit_identical_to_scalar_scan_on_adversarial_weights() {
    let cfg = CodingConfig::default();
    let tables = build_cost_tables(&WeightContexts::new(cfg), 48);
    let mut rng = Pcg64::new(0x51D3);
    for _ in 0..400 {
        let w = adversarial(&mut rng, 1)[0];
        let f = match rng.below(4) {
            0 => 1.0,
            1 => 0.0,
            2 => f32::NAN,
            _ => rng.next_f64() as f32 * 3.0,
        };
        let delta = rng.next_f64() as f32 * 0.2 + 1e-4;
        let lambda = rng.next_f64() as f32 * 0.5;
        for table in &tables {
            let want = ref_argmin_rd(w, f, delta, lambda, &table.cost, table.half);
            assert_eq!(
                argmin_rd(w, f, delta, lambda, table),
                want,
                "w={w} f={f} delta={delta} lambda={lambda}"
            );
            // The windowed argmin only has defined window placement for
            // finite w (nn derives from w/delta); pin it on those.
            if w.is_finite() {
                let nn = ((w / delta).round() as i64)
                    .clamp(-(table.half as i64), table.half as i64) as i32;
                let sign = if w < 0.0 { -1f32 } else { 1f32 };
                let hi = nn.abs().saturating_add(8).min(table.half);
                // longhand windowed reference: a ascends 0..=hi on w's side
                let mut best = f32::INFINITY;
                let mut best_a = 0i32;
                for a in 0..=hi {
                    let idx = (table.half + if sign > 0.0 { a } else { -a }) as usize;
                    let d = w - sign * delta * a as f32;
                    let total = f * d * d + lambda * table.cost[idx];
                    if total < best {
                        best = total;
                        best_a = a;
                    }
                }
                assert_eq!(
                    argmin_rd_window(w, f, delta, lambda, table),
                    sign as i32 * best_a,
                    "window w={w} f={f} delta={delta} lambda={lambda}"
                );
            }
        }
    }
}

#[test]
fn sliced_streams_and_planes_are_identical_across_interleave_widths() {
    // Randomized slice layouts: plane size and slice length drawn per
    // round, so group widths regularly straddle the slice count and the
    // tail slice is short.  The encoded stream is scalar-deterministic;
    // every (interleave, threads) decode of it must agree bit-for-bit.
    let cfg = CodingConfig::default();
    let mut rng = Pcg64::new(0x1EAF);
    for round in 0..12 {
        let n = 500 + rng.below(8_000) as usize;
        let slice_len = 1 + rng.below(2_000) as usize;
        let values: Vec<i32> = (0..n)
            .map(|_| {
                if rng.next_f64() < 0.7 {
                    0
                } else {
                    rng.below(63) as i32 - 31
                }
            })
            .collect();
        let raw = encode_layer_sliced(&values, cfg, slice_len);
        let delta = 0.03125f32;
        let seq = decode_layer_sliced_interleaved(&raw, n, cfg, 1, 1).unwrap();
        assert_eq!(seq, values, "round={round}");
        let mut seq_f = vec![f32::NAN; n];
        decode_layer_dequant_sliced_into_interleaved(&raw, cfg, delta, 1, 1, &mut seq_f).unwrap();
        let k = 2 + rng.below((MAX_DECODE_INTERLEAVE - 1) as u64) as usize;
        for threads in [1usize, 3] {
            let ints = decode_layer_sliced_interleaved(&raw, n, cfg, threads, k).unwrap();
            assert_eq!(ints, seq, "round={round} k={k} threads={threads}");
            let mut floats = vec![f32::NAN; n];
            decode_layer_dequant_sliced_into_interleaved(&raw, cfg, delta, threads, k, &mut floats)
                .unwrap();
            for (i, (a, b)) in seq_f.iter().zip(&floats).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "round={round} k={k} threads={threads} i={i}"
                );
            }
        }
    }
}

fn sample_container(seed: u64, layers: usize) -> CompressedNetwork {
    let mut rng = Pcg64::new(seed);
    let mk = |name: &str, rows: usize, cols: usize, rng: &mut Pcg64| QuantizedLayer {
        name: name.into(),
        kind: Kind::Dense,
        shape: vec![cols, rows],
        rows,
        cols,
        ints: (0..rows * cols)
            .map(|_| {
                if rng.next_f64() < 0.75 {
                    0
                } else {
                    rng.below(31) as i32 - 15
                }
            })
            .collect(),
        delta: 0.01 + rng.next_f64() as f32 * 0.1,
        bias: None,
    };
    CompressedNetwork {
        name: "simd_identity".into(),
        cfg: CodingConfig::default(),
        layers: (0..layers)
            .map(|i| mk(&format!("l{i}"), 30 + i * 7, 40 + i * 3, &mut rng))
            .collect(),
    }
}

#[test]
fn container_decode_paths_agree_bitwise_across_schedules() {
    // Two-pass (from_bytes + dequantize) vs fused arena decode at every
    // interleave width: one network, all container versions, cross-layer
    // groups (the arena interleaves slices across layer boundaries, so
    // lanes carry different deltas).
    let net = sample_container(0xD1CE, 3);
    for policy in [
        ContainerPolicy::v1(),
        ContainerPolicy::v2(300, 2),
        ContainerPolicy::v3(300, 2),
    ] {
        let bytes = net.to_bytes_with(policy);
        let expected = CompressedNetwork::from_bytes(&bytes).unwrap().reconstruct_named();
        let mut arena = DecodeArena::new();
        for k in [1usize, 2, 4, MAX_DECODE_INTERLEAVE] {
            for threads in [1usize, 4] {
                let got = decode_network_into_with(&bytes, threads, k, &mut arena).unwrap();
                for (a, b) in got.layers.iter().zip(&expected.layers) {
                    assert_eq!(a.weights.len(), b.weights.len());
                    for (x, y) in a.weights.iter().zip(&b.weights) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "v{} k={k} threads={threads}",
                            policy.version
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn reencoding_is_byte_identical_across_schedules() {
    // Encoded bytes must not depend on any decode-side knob: decode a
    // container at several interleave widths, re-encode each
    // reconstruction, and require byte equality.  (Stream *production*
    // never ran SIMD or interleaved code — this guards against accidental
    // coupling.)
    let net = sample_container(0xBEEF, 2);
    let policy = ContainerPolicy::v3(256, 2);
    let bytes = net.to_bytes_with(policy);
    let reference = CompressedNetwork::from_bytes(&bytes).unwrap();
    let reencoded = reference.to_bytes_with(policy);
    assert_eq!(reencoded, bytes);
    for k in [1usize, 4, MAX_DECODE_INTERLEAVE] {
        // Exercise the interleaved arena decode, then re-encode through the
        // two-pass path again: the emitted bytes must not have moved.
        let mut arena = DecodeArena::new();
        decode_network_into_with(&bytes, 2, k, &mut arena).unwrap();
        let roundtrip = CompressedNetwork::from_bytes(&bytes).unwrap().to_bytes_with(policy);
        assert_eq!(roundtrip, bytes, "k={k}");
    }
}
