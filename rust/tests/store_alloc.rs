#![allow(clippy::disallowed_methods, clippy::disallowed_macros)] // outside the panic-free wall (clippy.toml)
//! Zero-allocation pin for the ModelStore request path: with the arena
//! pool saturated, warm-hit decode requests from 16 concurrent clients
//! must not touch the heap at all — admission (semaphore), registry
//! lookup, arena checkout, the fused inline decode, the user closure and
//! arena check-in included.
//!
//! Same harness discipline as `arena_alloc.rs`: a counting global
//! allocator, a single `#[test]` so no sibling test thread can allocate
//! during a measured window, and the MINIMUM allocation delta over
//! several barrier-bracketed rounds — the steady state is proven by any
//! round observing zero, while a late arena-pool growth event (the pool
//! only reaches its high-water size when 16 checkouts actually overlap)
//! or stray harness activity can only force a retry, never a false PASS.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Barrier;

use deepcabac::api::{AdmissionPolicy, ModelStore, StoreConfig};
use deepcabac::cabac::CodingConfig;
use deepcabac::model::{CompressedNetwork, ContainerPolicy, Kind, QuantizedLayer};
use deepcabac::util::Pcg64;

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const CLIENTS: usize = 16;
const REQS_PER_ROUND: usize = 8;
const WARMUP_ROUNDS: usize = 4;
const MEASURED_ROUNDS: usize = 5;

fn sample_container() -> Vec<u8> {
    let mut rng = Pcg64::new(0x570_A110C);
    let ints = (0..48 * 160)
        .map(|_| {
            if rng.next_f64() < 0.75 {
                0
            } else {
                rng.below(61) as i32 - 30
            }
        })
        .collect();
    let net = CompressedNetwork {
        name: "store_alloc_probe".into(),
        cfg: CodingConfig::default(),
        layers: vec![QuantizedLayer {
            name: "fc".into(),
            kind: Kind::Dense,
            shape: vec![160, 48],
            rows: 48,
            cols: 160,
            ints,
            delta: 0.015625,
            bias: Some((0..48).map(|r| r as f32 * 0.25).collect()),
        }],
    };
    net.to_bytes_with(ContainerPolicy::v3(1024, 1))
}

#[test]
fn warm_store_requests_are_allocation_free_at_16_clients() {
    let store = ModelStore::new(StoreConfig {
        // Headroom above the 16-checkout high-water mark: check-ins never
        // evict, so a warm round is pure swap_remove + push bookkeeping.
        arena_capacity: 32,
        max_in_flight: 32,
        admission: AdmissionPolicy::Block,
        // Inline per-request decode: the measured window exercises the
        // cross-request scaling configuration the serve bench gates on.
        decode_threads: 1,
        // Default limits/deadline/quarantine: the hardened bookkeeping
        // (Copy fields + atomic counters) must itself stay allocation-free
        // on the warm path — that's part of what this pin now covers.
        ..StoreConfig::default()
    });
    store.register("probe", sample_container()).unwrap();

    let rounds = WARMUP_ROUNDS + MEASURED_ROUNDS;
    let start = Barrier::new(CLIENTS + 1);
    let done = Barrier::new(CLIENTS + 1);
    let mut min_delta = usize::MAX;
    std::thread::scope(|s| {
        for _ in 0..CLIENTS {
            s.spawn(|| {
                for _ in 0..rounds {
                    start.wait();
                    for _ in 0..REQS_PER_ROUND {
                        let w = store
                            .decode("probe", |net| {
                                net.layers.first().and_then(|l| l.weights.first()).copied()
                            })
                            .unwrap();
                        assert!(w.is_some());
                    }
                    done.wait();
                }
            });
        }
        for round in 0..rounds {
            // Clients are parked in `start.wait()` here, so the counter
            // read brackets exactly one round of concurrent serving.
            let before = ALLOC_CALLS.load(Ordering::SeqCst);
            start.wait();
            done.wait();
            let delta = ALLOC_CALLS.load(Ordering::SeqCst) - before;
            if round >= WARMUP_ROUNDS {
                min_delta = min_delta.min(delta);
            }
        }
    });
    assert_eq!(
        min_delta, 0,
        "warm-hit serving round performed {min_delta} heap allocations \
         across {CLIENTS} concurrent clients"
    );

    // Sanity on the warm-path accounting: far more hits than the (at most
    // 16-deep) pool of cold builds, and nothing was ever evicted or shed.
    let st = store.stats();
    let total = (rounds * CLIENTS * REQS_PER_ROUND) as u64;
    assert_eq!(st.requests, total);
    assert!(st.arena_misses <= CLIENTS as u64, "{st:?}");
    assert_eq!(st.arena_hits, total - st.arena_misses);
    assert_eq!(st.evictions, 0);
    assert_eq!(st.rejected, 0);
}
