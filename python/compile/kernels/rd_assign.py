"""Layer-1 Pallas kernel: RDOQ assignment (paper eq. 11) — the quantization
hot-spot of DeepCABAC.

TPU mapping (DESIGN.md §1, Hardware-Adaptation): the weight vector is tiled
into VMEM blocks over a 1-D grid; the bit-cost table ``cost[K]`` is small and
re-fetched per block (it would be pinned in VMEM on real hardware via a
constant BlockSpec).  The K-way argmin is elementwise/reduction work for the
VPU — the MXU is intentionally idle, this kernel is VPU/bandwidth-bound.
VMEM budget per block (BLOCK=512, K<=2049): 512*4 B (w) + 512*4 B (fim)
+ 2049*4 B (cost) + 512*4*(running best/obj) ≈ 16 KiB « 16 MiB VMEM.

Lowered with ``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic
custom-calls); numerics are identical to the TPU path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _rd_assign_kernel(delta_ref, lam_ref, w_ref, fim_ref, cost_ref, out_ref):
    """One grid step: assign BLOCK weights against the full K-point grid.

    Running-argmin over the K axis is materialized as a (BLOCK, K) objective
    followed by an argmin reduction — on TPU this keeps a single VMEM-resident
    tile and one pass over the cost table (K is small); see fori_loop variant
    note in DESIGN.md §8.
    """
    w = w_ref[...]                      # (BLOCK,)
    fim = fim_ref[...]                  # (BLOCK,)
    cost = cost_ref[...]                # (K,)
    delta = delta_ref[0]
    lam = lam_ref[0]
    k = cost.shape[0]
    half = (k - 1) // 2
    grid_idx = jax.lax.iota(jnp.int32, k) - half
    q = delta * grid_idx.astype(jnp.float32)                    # (K,)
    obj = fim[:, None] * (w[:, None] - q[None, :]) ** 2 + lam * cost[None, :]
    out_ref[...] = jnp.argmin(obj, axis=1).astype(jnp.int32) - half


@functools.partial(jax.jit, static_argnames=())
def rd_assign(w, fim, delta, lam, cost):
    """Pallas RDOQ assignment.  Semantics == kernels.ref.rd_assign_ref.

    Args:
      w, fim: (n,) f32 with n % BLOCK == 0 (the AOT wrapper pads).
      delta, lam: (1,) f32 scalars (SMEM-style prefetch operands).
      cost: (k,) f32 bit-cost table, k odd.
    Returns: (n,) int32 signed grid indices.
    """
    n = w.shape[0]
    assert n % BLOCK == 0, f"n={n} must be a multiple of {BLOCK}"
    k = cost.shape[0]
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _rd_assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),          # delta
            pl.BlockSpec((1,), lambda i: (0,)),          # lam
            pl.BlockSpec((BLOCK,), lambda i: (i,)),      # w tile
            pl.BlockSpec((BLOCK,), lambda i: (i,)),      # fim tile
            pl.BlockSpec((k,), lambda i: (0,)),          # cost (resident)
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(delta, lam, w, fim, cost)
