"""Pure-jnp oracles for the Pallas kernels (correctness references).

These define the semantics; the Pallas kernels in rd_assign.py / dequant.py
must match them bit-for-bit in f32 (pytest + hypothesis enforce this).
"""

from __future__ import annotations

import jax.numpy as jnp


def rd_assign_ref(w, fim, delta, lam, cost):
    """RDOQ assignment, paper eq. (11).

    Args:
      w:     (n,) f32 weights.
      fim:   (n,) f32 per-weight importance F_i (>= 0).
      delta: scalar f32 step-size (> 0).
      lam:   scalar f32 rate multiplier (>= 0).
      cost:  (k,) f32 bit-cost of grid index I_j = j - (k-1)//2 as estimated
             by CABAC (context-frozen table supplied by the Rust coordinator).

    Returns:
      (n,) int32 signed grid indices I in [-(k-1)//2, (k-1)//2] minimizing
      F_i (w_i - delta*I)^2 + lam * cost[I].  Ties resolve to the smallest
      grid position (argmin first-occurrence), matching the kernel and the
      Rust reference implementation.
    """
    k = cost.shape[0]
    half = (k - 1) // 2
    idx = jnp.arange(k, dtype=jnp.int32) - half          # signed grid
    q = delta * idx.astype(jnp.float32)                  # (k,)
    dist = fim[:, None] * (w[:, None] - q[None, :]) ** 2  # (n,k)
    obj = dist + lam * cost[None, :]
    return jnp.argmin(obj, axis=1).astype(jnp.int32) - half


def dequant_ref(idx, delta):
    """Reconstruction map Q^{-1}: q = delta * I  (paper sec. III-C.1)."""
    return idx.astype(jnp.float32) * delta
