"""Layer-1 Pallas kernel: dequantization q = delta * I (paper §III-C.1).

Trivially bandwidth-bound; exists so the reconstruction map Q^{-1} lives in
the same AOT artifact family as the assignment map Q, and so the L2 eval
graph can consume quantized indices directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _dequant_kernel(delta_ref, idx_ref, out_ref):
    out_ref[...] = idx_ref[...].astype(jnp.float32) * delta_ref[0]


@jax.jit
def dequant(idx, delta):
    """idx: (n,) int32 (n % BLOCK == 0); delta: (1,) f32 -> (n,) f32."""
    n = idx.shape[0]
    assert n % BLOCK == 0, f"n={n} must be a multiple of {BLOCK}"
    return pl.pallas_call(
        _dequant_kernel,
        grid=(n // BLOCK,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(delta, idx)
