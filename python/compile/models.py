"""Model zoo for the DeepCABAC reproduction (pure JAX, no flax).

Four architectures mirroring the paper's zoo at laptop scale (DESIGN.md §5):

  * ``lenet300``  — LeNet-300-100 MLP            (~107k params)
  * ``lenet5``    — small conv net               (~36k  params)
  * ``smallvgg``  — VGG-style conv stack         (~410k params, Table II/III)
  * ``mobilenet`` — depthwise-separable conv net (~47k  params)

Parameters live in an ordered list of layers.  Each layer is a dict with:
  name   : str
  kind   : 'dense' | 'conv' | 'dwconv'
  w      : weight array in its *compute* layout
             dense : (in, out)
             conv  : (kh, kw, cin, cout)  (HWIO)
             dwconv: (kh, kw, c, 1)
  b      : bias (cout,) or None

``to_matrix``/``from_matrix`` convert between the compute layout and the
paper's matrix scan form (§III-A footnote 3): rows = output channels,
columns = kh*kw*cin (im2col order, row-major scan).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D

# ---------------------------------------------------------------------------
# Layout helpers
# ---------------------------------------------------------------------------


def to_matrix(kind: str, w: jnp.ndarray) -> jnp.ndarray:
    """Compute layout -> paper matrix form (rows = out channels)."""
    if kind == "dense":
        return w.T  # (out, in)
    if kind in ("conv", "dwconv"):
        kh, kw, cin, cout = w.shape
        return w.reshape(kh * kw * cin, cout).T  # (cout, kh*kw*cin)
    raise ValueError(kind)


def from_matrix(kind: str, shape: tuple[int, ...], m: jnp.ndarray) -> jnp.ndarray:
    """Paper matrix form -> compute layout with original `shape`."""
    if kind == "dense":
        return m.T.reshape(shape)
    if kind in ("conv", "dwconv"):
        kh, kw, cin, cout = shape
        return m.T.reshape(kh, kw, cin, cout)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _dense(key, name, nin, nout):
    w = jax.random.normal(key, (nin, nout)) * np.sqrt(2.0 / nin)
    return dict(name=name, kind="dense", w=w.astype(jnp.float32),
                b=jnp.zeros((nout,), jnp.float32))


def _conv(key, name, kh, kw, cin, cout):
    w = jax.random.normal(key, (kh, kw, cin, cout)) * np.sqrt(2.0 / (kh * kw * cin))
    return dict(name=name, kind="conv", w=w.astype(jnp.float32),
                b=jnp.zeros((cout,), jnp.float32))


def _dwconv(key, name, kh, kw, c):
    w = jax.random.normal(key, (kh, kw, c, 1)) * np.sqrt(2.0 / (kh * kw))
    return dict(name=name, kind="dwconv", w=w.astype(jnp.float32),
                b=jnp.zeros((c,), jnp.float32))


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------

_DN = ("NHWC", "HWIO", "NHWC")


def conv2d(x, w, b, stride=1, groups=1):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=_DN, feature_group_count=groups)
    return y + b


def dwconv2d(x, w, b, stride=1):
    c = x.shape[-1]
    # depthwise: HWIO with I=1, feature_group_count=c expects (kh,kw,1,c)
    wd = jnp.transpose(w, (0, 1, 3, 2))  # (kh,kw,1,c)
    y = jax.lax.conv_general_dilated(
        x, wd, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=_DN, feature_group_count=c)
    return y + b


def maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


def relu(x):
    return jnp.maximum(x, 0.0)


# ---------------------------------------------------------------------------
# Architectures: init(key) -> layers, apply(layers, x) -> logits
# ---------------------------------------------------------------------------


def init_lenet300(key):
    ks = jax.random.split(key, 3)
    nin = D.IMG * D.IMG
    return [
        _dense(ks[0], "fc1", nin, 300),
        _dense(ks[1], "fc2", 300, 100),
        _dense(ks[2], "fc3", 100, D.N_CLASSES),
    ]


def apply_lenet300(layers, x):
    h = x.reshape(x.shape[0], -1)
    h = relu(h @ layers[0]["w"] + layers[0]["b"])
    h = relu(h @ layers[1]["w"] + layers[1]["b"])
    return h @ layers[2]["w"] + layers[2]["b"]


def init_lenet5(key):
    ks = jax.random.split(key, 4)
    return [
        _conv(ks[0], "conv1", 5, 5, 1, 8),
        _conv(ks[1], "conv2", 5, 5, 8, 16),
        _dense(ks[2], "fc1", 4 * 4 * 16, 64),
        _dense(ks[3], "fc2", 64, D.N_CLASSES),
    ]


def apply_lenet5(layers, x):
    h = maxpool(relu(conv2d(x, layers[0]["w"], layers[0]["b"])))      # 8x8x8
    h = maxpool(relu(conv2d(h, layers[1]["w"], layers[1]["b"])))      # 4x4x16
    h = h.reshape(h.shape[0], -1)
    h = relu(h @ layers[2]["w"] + layers[2]["b"])
    return h @ layers[3]["w"] + layers[3]["b"]


def init_smallvgg(key):
    ks = jax.random.split(key, 7)
    return [
        _conv(ks[0], "conv1_1", 3, 3, 1, 32),
        _conv(ks[1], "conv1_2", 3, 3, 32, 32),
        _conv(ks[2], "conv2_1", 3, 3, 32, 64),
        _conv(ks[3], "conv2_2", 3, 3, 64, 64),
        _conv(ks[4], "conv3_1", 3, 3, 64, 128),
        _dense(ks[5], "fc1", 2 * 2 * 128, 512),
        _dense(ks[6], "fc2", 512, D.N_CLASSES),
    ]


def apply_smallvgg(layers, x):
    h = relu(conv2d(x, layers[0]["w"], layers[0]["b"]))
    h = maxpool(relu(conv2d(h, layers[1]["w"], layers[1]["b"])))      # 8x8x32
    h = relu(conv2d(h, layers[2]["w"], layers[2]["b"]))
    h = maxpool(relu(conv2d(h, layers[3]["w"], layers[3]["b"])))      # 4x4x64
    h = maxpool(relu(conv2d(h, layers[4]["w"], layers[4]["b"])))      # 2x2x128
    h = h.reshape(h.shape[0], -1)
    h = relu(h @ layers[5]["w"] + layers[5]["b"])
    return h @ layers[6]["w"] + layers[6]["b"]


def init_mobilenet(key):
    ks = jax.random.split(key, 8)
    return [
        _conv(ks[0], "conv1", 3, 3, 1, 16),
        _dwconv(ks[1], "dw1", 3, 3, 16),
        _conv(ks[2], "pw1", 1, 1, 16, 64),
        _dwconv(ks[3], "dw2", 3, 3, 64),
        _conv(ks[4], "pw2", 1, 1, 64, 128),
        _dwconv(ks[5], "dw3", 3, 3, 128),
        _conv(ks[6], "pw3", 1, 1, 128, 256),
        _dense(ks[7], "fc", 256, D.N_CLASSES),
    ]


def apply_mobilenet(layers, x):
    h = relu(conv2d(x, layers[0]["w"], layers[0]["b"], stride=2))     # 8x8x16
    h = relu(dwconv2d(h, layers[1]["w"], layers[1]["b"]))
    h = relu(conv2d(h, layers[2]["w"], layers[2]["b"]))               # 8x8x64
    h = relu(dwconv2d(h, layers[3]["w"], layers[3]["b"], stride=2))   # 4x4x64
    h = relu(conv2d(h, layers[4]["w"], layers[4]["b"]))               # 4x4x128
    h = relu(dwconv2d(h, layers[5]["w"], layers[5]["b"], stride=2))   # 2x2x128
    h = relu(conv2d(h, layers[6]["w"], layers[6]["b"]))               # 2x2x256
    h = h.mean(axis=(1, 2))                                           # GAP
    return h @ layers[7]["w"] + layers[7]["b"]


ZOO = {
    "lenet300": (init_lenet300, apply_lenet300),
    "lenet5": (init_lenet5, apply_lenet5),
    "smallvgg": (init_smallvgg, apply_smallvgg),
    "mobilenet": (init_mobilenet, apply_mobilenet),
}

# Target sparsities for the pruned variants (fraction of weights KEPT),
# mirroring Table I's |w!=0|/|w| regime per architecture family.
SPARSE_KEEP = {
    "lenet300": 0.10,
    "lenet5": 0.08,
    "smallvgg": 0.10,
    "mobilenet": 0.50,
}


def param_count(layers) -> int:
    return int(sum(np.prod(l["w"].shape) for l in layers))


def apply_with_matrices(name: str, mats, biases, x):
    """Eval entrypoint used for AOT lowering: weights arrive in the paper's
    matrix scan form (what the Rust coordinator holds) and are reshaped to
    compute layout inside the graph, so Rust never needs layout logic."""
    init, apply = ZOO[name]
    template = init(jax.random.PRNGKey(0))
    layers = []
    for tpl, m, b in zip(template, mats, biases):
        layers.append(dict(name=tpl["name"], kind=tpl["kind"],
                           w=from_matrix(tpl["kind"], tpl["w"].shape, m), b=b))
    return apply(layers, x)
