"""Parameter-importance estimation (build time).

Two estimators, both exported into the .nwf container per layer:

  * ``fisher``  — empirical Fisher diagonal  F_i = E_data[(d/dw_i NLL)^2]
                  averaged over many per-example gradients + damping.
                  This is DC-v1's F_i (paper eq. 10/11; App. B argues
                  sigma_i^2 ~ beta / F_i, we use sigma_i = 1/sqrt(F_i)).
  * ``hessian`` — Hutchinson estimate of the loss-Hessian diagonal with few
                  Rademacher probes (noisy, can go negative -> clipped).
                  Used by the Fig. 8 ablation (Hessian- vs variance-weighted
                  Lloyd): the contrast in stability comes precisely from this
                  estimator's variance, as in [45] vs [26].
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import models as M
from .train import loss_fn, _tree_of


def _per_example_grad_sq(name, layers, x, y):
    """Sum over the batch of squared per-example weight gradients."""
    _, apply = M.ZOO[name]
    tree = _tree_of(layers)

    def single(tr, xi, yi):
        return loss_fn(tr, layers, apply, xi[None], yi[None])

    g = jax.vmap(jax.grad(single), in_axes=(None, 0, 0))(tree, x, y)
    return [(jnp.sum(gw ** 2, axis=0), jnp.sum(gb ** 2, axis=0))
            for gw, gb in g]


def fisher_diag(name, layers, x, y, batch=64, max_samples=1024, damping=1e-8):
    """Empirical Fisher diagonal per weight tensor (list of arrays)."""
    n = min(x.shape[0], max_samples)
    acc = None
    fn = jax.jit(partial(_per_example_grad_sq, name, layers))
    for i in range(0, n, batch):
        part = fn(x[i:i + batch], y[i:i + batch])
        if acc is None:
            acc = part
        else:
            acc = [(aw + pw, ab + pb) for (aw, ab), (pw, pb) in zip(acc, part)]
    return [np.asarray(aw / n) + damping for aw, _ in acc]


def hessian_diag(name, layers, x, y, probes=8, batch=256, seed=7):
    """Hutchinson diag(H) estimate: E[v * (H v)], v ~ Rademacher.

    Deliberately few probes/batches -> high-variance estimate (reproduces the
    instability the paper reports for Hessian-weighted Lloyd, Fig. 8)."""
    _, apply = M.ZOO[name]
    tree = _tree_of(layers)
    xb, yb = x[:batch], y[:batch]

    @jax.jit
    def hvp(v, xb, yb):
        grad_fn = jax.grad(lambda tr: loss_fn(tr, layers, apply, xb, yb))
        return jax.jvp(grad_fn, (tree,), (v,))[1]

    rng = np.random.default_rng(seed)
    acc = [np.zeros(l["w"].shape, np.float64) for l in layers]
    for _ in range(probes):
        v = [(jnp.asarray(rng.choice([-1.0, 1.0], size=l["w"].shape)
                          .astype(np.float32)),
              jnp.zeros_like(l["b"])) for l in layers]
        hv = hvp(v, xb, yb)
        for i, ((vw, _), (hw, _)) in enumerate(zip(v, hv)):
            acc[i] += np.asarray(vw * hw, np.float64)
    # Clip negatives (H diag estimates can dip below 0): keep PSD-ish weights.
    return [np.maximum(a / probes, 1e-10).astype(np.float32) for a in acc]
