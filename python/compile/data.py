"""SynthVision-16: deterministic synthetic 16x16 grayscale 10-class dataset.

Stand-in for MNIST/CIFAR10/ImageNet in the DeepCABAC reproduction (see
DESIGN.md section 6).  The compression pipeline only needs (a) trained weight
tensors with realistic statistics and (b) an accuracy oracle with a non-trivial
cliff under quantization; a class-conditional generative process over oriented
bars + Gaussian blobs provides both while being fully reproducible offline.

Each class c combines:
  * an oriented bar at angle  (c * 18 degrees)  through a class-specific center,
  * a Gaussian blob at a class-specific location,
  * per-sample random translation (+-2 px), amplitude jitter and pixel noise.

Classes are therefore linearly separable only partially; an MLP reaches
~90-99% and conv nets a bit more, mirroring the MNIST/CIFAR accuracy regime
of the paper's Table I protocol.
"""

from __future__ import annotations

import numpy as np

IMG = 16
N_CLASSES = 10
N_TRAIN = 4096
N_TEST = 1024
SEED = 0x5EED


def _class_params(c: int):
    """Deterministic per-class generative parameters."""
    angle = np.pi * c / N_CLASSES
    # Blob center walks a ring; bar center walks a smaller counter-ring.
    ring = 4.5
    bx = IMG / 2 + ring * np.cos(2 * np.pi * c / N_CLASSES)
    by = IMG / 2 + ring * np.sin(2 * np.pi * c / N_CLASSES)
    cx = IMG / 2 - 2.0 * np.cos(2 * np.pi * (c + 3) / N_CLASSES)
    cy = IMG / 2 - 2.0 * np.sin(2 * np.pi * (c + 3) / N_CLASSES)
    return angle, (bx, by), (cx, cy)


def _render(c: int, rng: np.random.Generator) -> np.ndarray:
    angle, (bx, by), (cx, cy) = _class_params(c)
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32)
    dx, dy = rng.uniform(-2.5, 2.5, size=2)
    amp_bar = rng.uniform(0.5, 1.3)
    amp_blob = rng.uniform(0.5, 1.3)

    # Oriented bar: distance from the line through (cx,cy) with direction angle.
    nx, ny = -np.sin(angle), np.cos(angle)
    d = (xx - (cx + dx)) * nx + (yy - (cy + dy)) * ny
    bar = amp_bar * np.exp(-(d ** 2) / (2 * 1.2 ** 2))

    # Blob.
    r2 = (xx - (bx + dx)) ** 2 + (yy - (by + dy)) ** 2
    blob = amp_blob * np.exp(-r2 / (2 * 2.0 ** 2))

    img = bar + blob + rng.normal(0, 0.5, size=(IMG, IMG)).astype(np.float32)
    return img.astype(np.float32)


def make_split(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` images (n % N_CLASSES == 0 gives exact class balance)."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n) % N_CLASSES
    rng.shuffle(labels)
    imgs = np.stack([_render(int(c), rng) for c in labels])
    # Global standardization with fixed constants (decoder-side friendly).
    imgs = (imgs - imgs.mean()) / (imgs.std() + 1e-8)
    return imgs[..., None].astype(np.float32), labels.astype(np.uint8)


def load(seed: int = SEED):
    """Return ((x_train, y_train), (x_test, y_test))."""
    tr = make_split(N_TRAIN, seed)
    te = make_split(N_TEST, seed + 1)
    return tr, te


def write_nds(path: str, images: np.ndarray, labels: np.ndarray) -> None:
    """Write the .nds dataset container (see DESIGN.md section 4).

    Layout (little-endian):
      magic 'NDS1' | u32 n | u32 h | u32 w | u32 c | u32 classes
      | f32 images (n*h*w*c, row-major) | u8 labels (n)
    """
    assert images.dtype == np.float32 and labels.dtype == np.uint8
    n, h, w, c = images.shape
    with open(path, "wb") as f:
        f.write(b"NDS1")
        np.array([n, h, w, c, N_CLASSES], dtype="<u4").tofile(f)
        images.astype("<f4").tofile(f)
        labels.tofile(f)


def read_nds(path: str):
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == b"NDS1", magic
        n, h, w, c, ncls = np.fromfile(f, dtype="<u4", count=5)
        imgs = np.fromfile(f, dtype="<f4", count=n * h * w * c).reshape(n, h, w, c)
        labels = np.fromfile(f, dtype=np.uint8, count=n)
    return imgs, labels, int(ncls)
