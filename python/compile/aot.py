"""AOT compile path: train the zoo, estimate importances, export artifacts.

Run as ``python -m compile.aot --out ../artifacts`` (the `make artifacts`
target).  Python runs ONCE here; the Rust coordinator is self-contained
afterwards.

Artifacts produced:
  dataset.nds                 test split (accuracy oracle input)
  <model>.nwf                 trained dense weights + fisher/hessian + biases
  <model>_sparse.nwf          magnitude-pruned variant (same shapes)
  eval_<model>.hlo.txt        (mats..., biases..., x[B,16,16,1]) -> logits
  rd_assign.hlo.txt           Pallas RDOQ kernel, n=16384, K=1025
  dequant.hlo.txt             Pallas dequant kernel, n=16384
  MANIFEST.txt                provenance + integrity listing (written last —
                              the Makefile's up-to-date sentinel)

HLO is exported as TEXT (not serialized HloModuleProto): jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import fim as FIM
from . import io_format as IO
from . import models as M
from . import train as T
from .kernels import dequant as KD
from .kernels import rd_assign as KR

EVAL_BATCH = 256
KERNEL_N = 16384
KERNEL_K = 1025


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def export_eval_graph(name: str, layers, out_path: str) -> None:
    """Lower (mats..., biases..., x) -> (logits,) for one architecture."""
    mat_specs = [jax.ShapeDtypeStruct(
        M.to_matrix(l["kind"], l["w"]).shape, jnp.float32) for l in layers]
    bias_specs = [jax.ShapeDtypeStruct(l["b"].shape, jnp.float32)
                  for l in layers]
    x_spec = jax.ShapeDtypeStruct((EVAL_BATCH, D.IMG, D.IMG, 1), jnp.float32)
    k = len(layers)

    def fn(*args):
        mats, biases, x = args[:k], args[k:2 * k], args[2 * k]
        return (M.apply_with_matrices(name, mats, biases, x),)

    lowered = jax.jit(fn).lower(*mat_specs, *bias_specs, x_spec)
    with open(out_path, "w") as f:
        f.write(to_hlo_text(lowered))


def export_kernels(out_dir: str) -> None:
    w = jax.ShapeDtypeStruct((KERNEL_N,), jnp.float32)
    fimv = jax.ShapeDtypeStruct((KERNEL_N,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((1,), jnp.float32)
    cost = jax.ShapeDtypeStruct((KERNEL_K,), jnp.float32)
    idx = jax.ShapeDtypeStruct((KERNEL_N,), jnp.int32)

    lowered = jax.jit(
        lambda *a: (KR.rd_assign(*a),)).lower(w, fimv, scalar, scalar, cost)
    with open(os.path.join(out_dir, "rd_assign.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(lambda i, d: (KD.dequant(i, d),)).lower(idx, scalar)
    with open(os.path.join(out_dir, "dequant.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))


def layers_to_nwf(layers, fisher, hessian):
    out = []
    for i, l in enumerate(layers):
        mat = np.asarray(M.to_matrix(l["kind"], l["w"]))
        fi = np.asarray(M.to_matrix(l["kind"], jnp.asarray(fisher[i]))) \
            if fisher is not None else None
        he = np.asarray(M.to_matrix(l["kind"], jnp.asarray(hessian[i]))) \
            if hessian is not None else None
        out.append(dict(name=l["name"], kind=l["kind"],
                        shape=tuple(int(s) for s in l["w"].shape),
                        mat=mat, fisher=fi, hessian=he,
                        bias=np.asarray(l["b"])))
    return out


TRAIN_STEPS = {"lenet300": 700, "lenet5": 700, "smallvgg": 900,
               "mobilenet": 900}
FINETUNE_STEPS = {"lenet300": 250, "lenet5": 250, "smallvgg": 300,
                  "mobilenet": 300}


def build_model(name, xy_train, xy_test, out_dir, manifest):
    (x_tr, y_tr), (x_te, y_te) = xy_train, xy_test
    key = jax.random.PRNGKey(hash(name) % (2 ** 31))
    init, _ = M.ZOO[name]
    layers = init(key)
    print(f"[aot] training {name} ({M.param_count(layers)} params)")
    layers, acc = T.train(name, layers, x_tr, y_tr, x_te, y_te,
                          steps=TRAIN_STEPS[name])

    print(f"[aot] importance estimation for {name}")
    fisher = FIM.fisher_diag(name, layers, x_te, y_te)
    hessian = FIM.hessian_diag(name, layers, x_te, y_te)
    IO.write_nwf(os.path.join(out_dir, f"{name}.nwf"),
                 layers_to_nwf(layers, fisher, hessian))
    manifest["models"][name] = dict(
        params=M.param_count(layers), top1=float(acc),
        layers=[l["name"] for l in layers])

    print(f"[aot] sparsifying {name}")
    sparse, sacc = T.magnitude_prune(
        layers, M.SPARSE_KEEP[name], rounds=3, name=name,
        xy_train=(x_tr, y_tr), xy_test=(x_te, y_te),
        steps=FINETUNE_STEPS[name])
    sf = FIM.fisher_diag(name, sparse, x_te, y_te)
    sh = FIM.hessian_diag(name, sparse, x_te, y_te)
    IO.write_nwf(os.path.join(out_dir, f"{name}_sparse.nwf"),
                 layers_to_nwf(sparse, sf, sh))
    nz = sum(float((np.asarray(l["w"]) != 0).sum()) for l in sparse)
    tot = M.param_count(sparse)
    manifest["models"][f"{name}_sparse"] = dict(
        params=tot, top1=float(sacc), nonzero_frac=nz / tot,
        layers=[l["name"] for l in sparse])

    print(f"[aot] lowering eval graph for {name}")
    export_eval_graph(name, layers,
                      os.path.join(out_dir, f"eval_{name}.hlo.txt"))

    # Golden logits on the first eval batch: the Rust runtime integration
    # test executes eval_<name>.hlo.txt with the dense weights + this batch
    # and must reproduce these values (rtol ~1e-5).
    _, apply = M.ZOO[name]
    logits = np.asarray(apply(layers, x_te[:EVAL_BATCH]), dtype="<f4")
    logits.tofile(os.path.join(out_dir, f"golden_logits_{name}.bin"))
    return layers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="lenet300,lenet5,smallvgg,mobilenet")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()

    manifest = {"models": {}, "eval_batch": EVAL_BATCH,
                "kernel_n": KERNEL_N, "kernel_k": KERNEL_K}

    print("[aot] generating SynthVision-16")
    (x_tr, y_tr), (x_te, y_te) = D.load()
    D.write_nds(os.path.join(args.out, "dataset.nds"), x_te, y_te)
    x_tr_j, y_tr_j = jnp.asarray(x_tr), jnp.asarray(y_tr.astype(np.int32))
    x_te_j, y_te_j = jnp.asarray(x_te), jnp.asarray(y_te.astype(np.int32))

    for name in args.models.split(","):
        build_model(name, (x_tr_j, y_tr_j), (x_te_j, y_te_j),
                    args.out, manifest)

    print("[aot] lowering Pallas kernels")
    export_kernels(args.out)

    # MANIFEST last: it is the Makefile's freshness sentinel.
    files = sorted(f for f in os.listdir(args.out) if f != "MANIFEST.txt")
    listing = []
    for f in files:
        p = os.path.join(args.out, f)
        h = hashlib.sha256(open(p, "rb").read()).hexdigest()[:16]
        listing.append(f"{f}  {os.path.getsize(p)}  {h}")
    manifest["elapsed_sec"] = round(time.time() - t0, 1)
    with open(os.path.join(args.out, "MANIFEST.txt"), "w") as f:
        f.write(json.dumps(manifest, indent=2) + "\n")
        f.write("\n".join(listing) + "\n")
    print(f"[aot] done in {manifest['elapsed_sec']}s -> {args.out}")


if __name__ == "__main__":
    main()
