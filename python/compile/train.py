"""Build-time training for the model zoo (hand-rolled Adam, pure JAX).

Runs once inside ``make artifacts``; nothing here is on the request path.
Supports masked training for the magnitude-pruned sparse variants
(the mask is re-applied after every update, standard iterative pruning).
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import models as M


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def _tree_of(layers):
    return [(l["w"], l["b"]) for l in layers]


def _layers_with(layers, tree):
    out = []
    for l, (w, b) in zip(layers, tree):
        out.append(dict(l, w=w, b=b))
    return out


def loss_fn(tree, template, apply, x, y):
    layers = _layers_with(template, tree)
    return cross_entropy(apply(layers, x), y)


def accuracy(layers, apply, x, y, batch=256):
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = apply(layers, x[i:i + batch])
        correct += int((jnp.argmax(logits, axis=1) == y[i:i + batch]).sum())
    return correct / x.shape[0]


def train(name: str, layers, x_train, y_train, x_test, y_test, *,
          steps=600, batch=128, lr=2e-3, masks=None, seed=0, log=print):
    """Adam training loop; if `masks` is given (list of 0/1 arrays matching
    each layer's weight), weights are re-masked after every step."""
    _, apply = M.ZOO[name]
    tree = _tree_of(layers)
    m = [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in tree]
    v = [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in tree]
    b1, b2, eps = 0.9, 0.999, 1e-8

    grad_fn = jax.jit(jax.value_and_grad(
        partial(loss_fn, template=layers, apply=apply)))

    @jax.jit
    def step(tree, m, v, t, x, y, masks):
        loss, g = jax.value_and_grad(
            lambda tr: loss_fn(tr, layers, apply, x, y))(tree)
        new_tree, new_m, new_v = [], [], []
        for i, ((w, b), (gw, gb)) in enumerate(zip(tree, g)):
            mw, mb = m[i]
            vw, vb = v[i]
            mw = b1 * mw + (1 - b1) * gw
            mb = b1 * mb + (1 - b1) * gb
            vw = b2 * vw + (1 - b2) * gw * gw
            vb = b2 * vb + (1 - b2) * gb * gb
            c1 = 1 - b1 ** t
            c2 = 1 - b2 ** t
            w = w - lr * (mw / c1) / (jnp.sqrt(vw / c2) + eps)
            b = b - lr * (mb / c1) / (jnp.sqrt(vb / c2) + eps)
            if masks is not None:
                w = w * masks[i]
            new_tree.append((w, b))
            new_m.append((mw, mb))
            new_v.append((vw, vb))
        return loss, new_tree, new_m, new_v

    rng = np.random.default_rng(seed)
    n = x_train.shape[0]
    t0 = time.time()
    loss = float("nan")
    for s in range(1, steps + 1):
        idx = rng.integers(0, n, size=batch)
        loss, tree, m, v = step(tree, m, v, jnp.float32(s),
                                x_train[idx], y_train[idx], masks)
        if s % 200 == 0 or s == steps:
            log(f"  [{name}] step {s}/{steps} loss={float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")
    out = _layers_with(layers, tree)
    acc = accuracy(out, apply, x_test, y_test)
    log(f"  [{name}] test acc = {acc * 100:.2f}%")
    return out, acc


def magnitude_prune(layers, keep: float, rounds=3, **train_kw):
    """Iterative global magnitude pruning to `keep` fraction of weights,
    with fine-tuning between rounds (stand-in for variational dropout [26],
    see DESIGN.md section 6)."""
    name = train_kw.pop("name")
    x_train, y_train = train_kw.pop("xy_train")
    x_test, y_test = train_kw.pop("xy_test")
    log = train_kw.get("log", print)
    cur = layers
    for r in range(1, rounds + 1):
        frac = keep ** (r / rounds)  # geometric schedule
        allw = np.concatenate([np.abs(np.asarray(l["w"]).ravel()) for l in cur])
        thresh = np.quantile(allw, 1.0 - frac)
        masks = [jnp.asarray((np.abs(np.asarray(l["w"])) > thresh)
                             .astype(np.float32)) for l in cur]
        cur = [dict(l, w=l["w"] * mk) for l, mk in zip(cur, masks)]
        cur, acc = train(name, cur, x_train, y_train, x_test, y_test,
                         masks=masks, **train_kw)
        nz = sum(float(mk.sum()) for mk in masks)
        tot = sum(mk.size for mk in masks)
        log(f"  [{name}-sparse] round {r}: keep={nz / tot * 100:.2f}% "
            f"acc={acc * 100:.2f}%")
    return cur, acc
