"""Compatibility shim: the L2 model definitions live in models.py."""
from .models import *  # noqa: F401,F403
from .models import ZOO, apply_with_matrices, to_matrix, from_matrix  # noqa: F401
