"""Writers/readers for the .nwf network-weight container (DESIGN.md §4).

Layout (all little-endian):

  magic 'NWF1'
  u32 n_layers
  per layer:
    u16 name_len | name bytes (utf-8)
    u8  kind            (0=dense, 1=conv, 2=dwconv)
    u8  n_dims          | u32 dims[n_dims]        -- compute-layout shape
    u32 rows | u32 cols                           -- matrix scan form
    u8  flags           (bit0: has fisher, bit1: has hessian, bit2: has bias)
    f32 weights[rows*cols]   (matrix form, row-major == paper scan order)
    f32 fisher[rows*cols]    (if flag)
    f32 hessian[rows*cols]   (if flag)
    u32 bias_len | f32 bias[bias_len]             (if flag)
  u32 crc32 of everything after the magic

The matrix form is rows = output channels, cols = kh*kw*cin (conv, im2col
order per [22]) or cols = fan-in (dense) -- see models.to_matrix.
"""

from __future__ import annotations

import struct
import zlib

import numpy as np

KIND_CODE = {"dense": 0, "conv": 1, "dwconv": 2}
KIND_NAME = {v: k for k, v in KIND_CODE.items()}


def write_nwf(path: str, layers: list[dict]) -> None:
    """`layers`: list of dicts with keys name, kind, shape (tuple),
    mat (2-D f32, matrix scan form), fisher (2-D or None),
    hessian (2-D or None), bias (1-D or None)."""
    body = bytearray()
    body += struct.pack("<I", len(layers))
    for l in layers:
        name = l["name"].encode()
        body += struct.pack("<H", len(name)) + name
        body += struct.pack("<B", KIND_CODE[l["kind"]])
        dims = l["shape"]
        body += struct.pack("<B", len(dims))
        body += struct.pack(f"<{len(dims)}I", *dims)
        mat = np.ascontiguousarray(l["mat"], dtype="<f4")
        rows, cols = mat.shape
        body += struct.pack("<II", rows, cols)
        flags = ((l.get("fisher") is not None) * 1
                 | (l.get("hessian") is not None) * 2
                 | (l.get("bias") is not None) * 4)
        body += struct.pack("<B", flags)
        body += mat.tobytes()
        if l.get("fisher") is not None:
            f = np.ascontiguousarray(l["fisher"], dtype="<f4")
            assert f.shape == mat.shape
            body += f.tobytes()
        if l.get("hessian") is not None:
            h = np.ascontiguousarray(l["hessian"], dtype="<f4")
            assert h.shape == mat.shape
            body += h.tobytes()
        if l.get("bias") is not None:
            b = np.ascontiguousarray(l["bias"], dtype="<f4").ravel()
            body += struct.pack("<I", b.size) + b.tobytes()
    crc = zlib.crc32(bytes(body)) & 0xFFFFFFFF
    with open(path, "wb") as f:
        f.write(b"NWF1")
        f.write(body)
        f.write(struct.pack("<I", crc))


def read_nwf(path: str) -> list[dict]:
    with open(path, "rb") as f:
        raw = f.read()
    assert raw[:4] == b"NWF1"
    body, crc_stored = raw[4:-4], struct.unpack("<I", raw[-4:])[0]
    assert zlib.crc32(body) & 0xFFFFFFFF == crc_stored, "nwf crc mismatch"
    off = 0

    def take(fmt):
        nonlocal off
        vals = struct.unpack_from("<" + fmt, body, off)
        off += struct.calcsize("<" + fmt)
        return vals

    (n_layers,) = take("I")
    layers = []
    for _ in range(n_layers):
        (name_len,) = take("H")
        name = body[off:off + name_len].decode()
        off += name_len
        (kind_code,) = take("B")
        (nd,) = take("B")
        dims = take(f"{nd}I")
        rows, cols = take("II")
        (flags,) = take("B")
        n = rows * cols

        def arr(count):
            nonlocal off
            a = np.frombuffer(body, dtype="<f4", count=count, offset=off).copy()
            off += 4 * count
            return a

        mat = arr(n).reshape(rows, cols)
        fisher = arr(n).reshape(rows, cols) if flags & 1 else None
        hessian = arr(n).reshape(rows, cols) if flags & 2 else None
        bias = None
        if flags & 4:
            (blen,) = take("I")
            bias = arr(blen)
        layers.append(dict(name=name, kind=KIND_NAME[kind_code],
                           shape=tuple(dims), mat=mat, fisher=fisher,
                           hessian=hessian, bias=bias))
    return layers
