""".nwf container: python-side roundtrip + golden binary layout checks.

The Rust reader has mirror tests against the same layout; byte-level goldens
here pin the format so both sides cannot drift silently.
"""

import os
import struct
import tempfile
import zlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import io_format as IO


def _mk_layer(name="l0", kind="dense", rows=4, cols=6, fisher=True,
              hessian=False, bias=True, seed=0):
    rng = np.random.default_rng(seed)
    shape = (cols, rows) if kind == "dense" else (1, 1, cols, rows)
    return dict(
        name=name, kind=kind, shape=shape,
        mat=rng.normal(size=(rows, cols)).astype(np.float32),
        fisher=rng.uniform(0, 1, (rows, cols)).astype(np.float32)
        if fisher else None,
        hessian=rng.uniform(0, 1, (rows, cols)).astype(np.float32)
        if hessian else None,
        bias=rng.normal(size=rows).astype(np.float32) if bias else None,
    )


def _roundtrip(layers):
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.nwf")
        IO.write_nwf(p, layers)
        return IO.read_nwf(p)


def test_roundtrip_single():
    layers = [_mk_layer()]
    back = _roundtrip(layers)
    assert back[0]["name"] == "l0"
    assert back[0]["kind"] == "dense"
    assert back[0]["shape"] == layers[0]["shape"]
    np.testing.assert_array_equal(back[0]["mat"], layers[0]["mat"])
    np.testing.assert_array_equal(back[0]["fisher"], layers[0]["fisher"])
    assert back[0]["hessian"] is None
    np.testing.assert_array_equal(back[0]["bias"], layers[0]["bias"])


def test_roundtrip_multi_kinds():
    layers = [
        _mk_layer("d", "dense", 3, 5, seed=1),
        _mk_layer("c", "conv", 8, 9, hessian=True, seed=2),
        _mk_layer("dw", "dwconv", 4, 9, fisher=False, bias=False, seed=3),
    ]
    back = _roundtrip(layers)
    assert [b["kind"] for b in back] == ["dense", "conv", "dwconv"]
    for a, b in zip(layers, back):
        np.testing.assert_array_equal(a["mat"], b["mat"])


def test_crc_detects_corruption():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.nwf")
        IO.write_nwf(p, [_mk_layer()])
        raw = bytearray(open(p, "rb").read())
        raw[20] ^= 0xFF
        open(p, "wb").write(bytes(raw))
        with pytest.raises(AssertionError):
            IO.read_nwf(p)


def test_golden_header_bytes():
    """Pin the on-disk prefix: magic, count, name, kind, dims."""
    layer = dict(name="ab", kind="conv", shape=(1, 2, 3, 4),
                 mat=np.zeros((4, 6), np.float32), fisher=None,
                 hessian=None, bias=None)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.nwf")
        IO.write_nwf(p, [layer])
        raw = open(p, "rb").read()
    assert raw[:4] == b"NWF1"
    assert struct.unpack_from("<I", raw, 4)[0] == 1          # n_layers
    assert struct.unpack_from("<H", raw, 8)[0] == 2          # name len
    assert raw[10:12] == b"ab"
    assert raw[12] == 1                                      # kind=conv
    assert raw[13] == 4                                      # n_dims
    assert struct.unpack_from("<4I", raw, 14) == (1, 2, 3, 4)
    rows, cols = struct.unpack_from("<II", raw, 30)
    assert (rows, cols) == (4, 6)
    assert raw[38] == 0                                      # flags
    # crc over body
    assert struct.unpack("<I", raw[-4:])[0] == zlib.crc32(raw[4:-4])


@settings(max_examples=20, deadline=None)
@given(
    n_layers=st.integers(1, 4),
    rows=st.integers(1, 12),
    cols=st.integers(1, 12),
    flags=st.tuples(st.booleans(), st.booleans(), st.booleans()),
    seed=st.integers(0, 1000),
)
def test_roundtrip_hypothesis(n_layers, rows, cols, flags, seed):
    fisher, hessian, bias = flags
    layers = [_mk_layer(f"l{i}", "dense", rows, cols, fisher, hessian,
                        bias, seed + i) for i in range(n_layers)]
    back = _roundtrip(layers)
    assert len(back) == n_layers
    for a, b in zip(layers, back):
        np.testing.assert_array_equal(a["mat"], b["mat"])
        for k in ("fisher", "hessian", "bias"):
            if a[k] is None:
                assert b[k] is None
            else:
                np.testing.assert_array_equal(a[k], b[k])
