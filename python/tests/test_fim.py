"""Importance estimators: shapes, positivity, signal checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import fim as F
from compile import models as M
from compile import train as T


@pytest.fixture(scope="module")
def trained_lenet300():
    (xtr, ytr) = D.make_split(640, 20)
    (xte, yte) = D.make_split(256, 21)
    xtr, ytr = jnp.asarray(xtr), jnp.asarray(ytr.astype(np.int32))
    xte, yte = jnp.asarray(xte), jnp.asarray(yte.astype(np.int32))
    init, _ = M.ZOO["lenet300"]
    layers = init(jax.random.PRNGKey(0))
    layers, _ = T.train("lenet300", layers, xtr, ytr, xte, yte,
                        steps=120, log=lambda *a: None)
    return layers, xte, yte


def test_fisher_shapes_and_positivity(trained_lenet300):
    layers, xte, yte = trained_lenet300
    fish = F.fisher_diag("lenet300", layers, xte, yte, max_samples=128)
    assert len(fish) == len(layers)
    for l, f in zip(layers, fish):
        assert f.shape == l["w"].shape
        assert (f > 0).all()          # damping guarantees strict positivity
        assert np.isfinite(f).all()


def test_fisher_has_signal(trained_lenet300):
    """Fisher must vary across weights (not a constant), spanning decades."""
    layers, xte, yte = trained_lenet300
    fish = F.fisher_diag("lenet300", layers, xte, yte, max_samples=128)
    f0 = fish[0].ravel()
    assert f0.max() / np.median(f0 + 1e-30) > 10


def test_hessian_shapes(trained_lenet300):
    layers, xte, yte = trained_lenet300
    hess = F.hessian_diag("lenet300", layers, xte, yte, probes=2)
    assert len(hess) == len(layers)
    for l, h in zip(layers, hess):
        assert h.shape == l["w"].shape
        assert (h > 0).all()          # clipped at 1e-10
        assert np.isfinite(h).all()


def test_hessian_noisier_than_fisher(trained_lenet300):
    """Few-probe Hutchinson is the noisy estimator (Fig. 8's premise):
    two independent estimates disagree more than two Fisher estimates."""
    layers, xte, yte = trained_lenet300
    h1 = F.hessian_diag("lenet300", layers, xte, yte, probes=2, seed=1)
    h2 = F.hessian_diag("lenet300", layers, xte, yte, probes=2, seed=2)
    f1 = F.fisher_diag("lenet300", layers, xte, yte, max_samples=128)
    f2 = F.fisher_diag("lenet300", layers, xte[128:], yte[128:],
                       max_samples=128)

    def rel_disagreement(a, b):
        a, b = a[0].ravel(), b[0].ravel()
        return float(np.mean(np.abs(a - b) / (np.abs(a) + np.abs(b) + 1e-12)))

    assert rel_disagreement(h1, h2) > rel_disagreement(f1, f2)
