"""AOT lowering: HLO text exports parse and have the right entry signature.

Numeric equivalence of the exported graphs is checked end-to-end by the Rust
runtime's integration tests against the golden logits that aot.py ships in
the artifacts (golden_logits_<model>.bin) — that is the cross-language check
that actually matters.
"""

import os
import tempfile

import jax
import pytest
from jax._src.lib import xla_client as xc

from compile import aot as A
from compile import data as D
from compile import models as M


@pytest.fixture(scope="module")
def lenet300_layers():
    init, _ = M.ZOO["lenet300"]
    return init(jax.random.PRNGKey(9))


def test_eval_hlo_text_parses(lenet300_layers):
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "eval.hlo.txt")
        A.export_eval_graph("lenet300", lenet300_layers, p)
        text = open(p).read()
    assert "ENTRY" in text
    assert f"f32[{A.EVAL_BATCH},{D.IMG},{D.IMG},1]" in text
    # parses back into an HloModule (same parser family the Rust side uses)
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_eval_hlo_param_order(lenet300_layers):
    """Entry params must be: k mats, k biases, then x — the order the Rust
    runtime feeds literals in."""
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "eval.hlo.txt")
        A.export_eval_graph("lenet300", lenet300_layers, p)
        text = open(p).read()
    entry = text[text.index("ENTRY"):]
    # The parser prints params as `Arg_N = TYPE parameter(N)` inside the
    # entry body; check each positional parameter has the expected type.
    # lenet300: mats (300,256) (100,300) (10,100); biases 300,100,10; x.
    expected = ["f32[300,256]", "f32[100,300]", "f32[10,100]",
                "f32[300]", "f32[100]", "f32[10]",
                f"f32[{A.EVAL_BATCH},16,16,1]"]
    import re
    for i, ty in enumerate(expected):
        pat = re.compile(
            re.escape(ty) + r"\{[^}]*\} parameter\(" + str(i) + r"\)")
        assert pat.search(entry), f"param {i} should be {ty}"


def test_kernel_hlo_exports(tmp_path):
    A.export_kernels(str(tmp_path))
    rd = open(tmp_path / "rd_assign.hlo.txt").read()
    dq = open(tmp_path / "dequant.hlo.txt").read()
    assert "ENTRY" in rd and "ENTRY" in dq
    assert f"f32[{A.KERNEL_N}]" in rd
    assert f"f32[{A.KERNEL_K}]" in rd
    assert f"s32[{A.KERNEL_N}]" in dq
    xc._xla.hlo_module_from_text(rd)
    xc._xla.hlo_module_from_text(dq)


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/MANIFEST.txt")),
    reason="artifacts not built")
def test_built_artifacts_complete():
    art = os.path.join(os.path.dirname(__file__), "../../artifacts")
    names = os.listdir(art)
    for model in ["lenet300", "lenet5", "smallvgg", "mobilenet"]:
        assert f"{model}.nwf" in names
        assert f"{model}_sparse.nwf" in names
        assert f"eval_{model}.hlo.txt" in names
        assert f"golden_logits_{model}.bin" in names
    assert "dataset.nds" in names
    assert "rd_assign.hlo.txt" in names and "dequant.hlo.txt" in names
