"""SynthVision-16 dataset invariants + .nds container roundtrip."""

import os
import tempfile

import numpy as np

from compile import data as D


def test_deterministic():
    a_imgs, a_lbl = D.make_split(200, 42)
    b_imgs, b_lbl = D.make_split(200, 42)
    assert (a_imgs == b_imgs).all() and (a_lbl == b_lbl).all()


def test_seed_changes_data():
    a_imgs, _ = D.make_split(100, 1)
    b_imgs, _ = D.make_split(100, 2)
    assert not (a_imgs == b_imgs).all()


def test_class_balance():
    _, lbl = D.make_split(1000, 0)
    counts = np.bincount(lbl, minlength=D.N_CLASSES)
    assert (counts == 100).all()


def test_shapes_and_dtype():
    imgs, lbl = D.make_split(50, 3)
    assert imgs.shape == (50, D.IMG, D.IMG, 1)
    assert imgs.dtype == np.float32 and lbl.dtype == np.uint8


def test_standardized():
    imgs, _ = D.make_split(500, 4)
    assert abs(imgs.mean()) < 0.05
    assert abs(imgs.std() - 1.0) < 0.05


def test_nds_roundtrip():
    imgs, lbl = D.make_split(30, 5)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.nds")
        D.write_nds(p, imgs, lbl)
        r_imgs, r_lbl, ncls = D.read_nds(p)
        assert ncls == D.N_CLASSES
        assert (r_imgs == imgs).all() and (r_lbl == lbl).all()


def test_classes_are_distinguishable():
    """Class-mean images must differ pairwise (separable generative process)."""
    imgs, lbl = D.make_split(500, 6)
    means = np.stack([imgs[lbl == c].mean(axis=0) for c in range(D.N_CLASSES)])
    for i in range(D.N_CLASSES):
        for j in range(i + 1, D.N_CLASSES):
            assert np.abs(means[i] - means[j]).max() > 0.1
