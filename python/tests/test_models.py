"""Model zoo: shapes, layouts, matrix-form roundtrips, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import models as M
from compile import train as T


@pytest.fixture(scope="module")
def tiny_data():
    (xtr, ytr), (xte, yte) = (D.make_split(640, 10), D.make_split(320, 11))
    return (jnp.asarray(xtr), jnp.asarray(ytr.astype(np.int32)),
            jnp.asarray(xte), jnp.asarray(yte.astype(np.int32)))


@pytest.mark.parametrize("name", list(M.ZOO))
def test_forward_shapes(name):
    init, apply = M.ZOO[name]
    layers = init(jax.random.PRNGKey(0))
    x = jnp.zeros((4, D.IMG, D.IMG, 1))
    logits = apply(layers, x)
    assert logits.shape == (4, D.N_CLASSES)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("name", list(M.ZOO))
def test_matrix_roundtrip(name):
    init, _ = M.ZOO[name]
    layers = init(jax.random.PRNGKey(1))
    for l in layers:
        mat = M.to_matrix(l["kind"], l["w"])
        assert mat.ndim == 2
        back = M.from_matrix(l["kind"], l["w"].shape, mat)
        assert (back == l["w"]).all()


@pytest.mark.parametrize("name", list(M.ZOO))
def test_matrix_rows_are_output_channels(name):
    init, _ = M.ZOO[name]
    layers = init(jax.random.PRNGKey(2))
    for l in layers:
        mat = M.to_matrix(l["kind"], l["w"])
        cout = l["w"].shape[-1] if l["kind"] != "dense" else l["w"].shape[1]
        assert mat.shape[0] == cout


@pytest.mark.parametrize("name", list(M.ZOO))
def test_apply_with_matrices_equals_apply(name):
    init, apply = M.ZOO[name]
    layers = init(jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (2, D.IMG, D.IMG, 1))
    mats = [M.to_matrix(l["kind"], l["w"]) for l in layers]
    biases = [l["b"] for l in layers]
    a = apply(layers, x)
    b = M.apply_with_matrices(name, mats, biases, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_param_counts_in_expected_bands():
    bands = {"lenet300": (90_000, 130_000), "lenet5": (15_000, 60_000),
             "smallvgg": (300_000, 600_000), "mobilenet": (30_000, 80_000)}
    for name, (lo, hi) in bands.items():
        init, _ = M.ZOO[name]
        n = M.param_count(init(jax.random.PRNGKey(0)))
        assert lo <= n <= hi, (name, n)


def test_training_reduces_loss(tiny_data):
    xtr, ytr, xte, yte = tiny_data
    init, apply = M.ZOO["lenet300"]
    layers = init(jax.random.PRNGKey(5))
    before = float(T.cross_entropy(apply(layers, xtr[:256]), ytr[:256]))
    layers, acc = T.train("lenet300", layers, xtr, ytr, xte, yte,
                          steps=120, log=lambda *a: None)
    after = float(T.cross_entropy(apply(layers, xtr[:256]), ytr[:256]))
    assert after < before * 0.5
    assert acc > 0.5


def test_magnitude_prune_hits_target(tiny_data):
    xtr, ytr, xte, yte = tiny_data
    init, _ = M.ZOO["lenet300"]
    layers = init(jax.random.PRNGKey(6))
    layers, _ = T.train("lenet300", layers, xtr, ytr, xte, yte,
                        steps=80, log=lambda *a: None)
    sparse, _ = T.magnitude_prune(layers, 0.2, rounds=2, name="lenet300",
                                  xy_train=(xtr, ytr), xy_test=(xte, yte),
                                  steps=40, log=lambda *a: None)
    nz = sum(float((np.asarray(l["w"]) != 0).sum()) for l in sparse)
    frac = nz / M.param_count(sparse)
    assert 0.15 <= frac <= 0.25
