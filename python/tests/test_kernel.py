"""Kernel-vs-reference correctness: the CORE L1 signal.

The Pallas kernels (interpret=True) must match the pure-jnp oracles exactly
(integer outputs -> bitwise; dequant is a single f32 multiply -> bitwise).
Hypothesis sweeps shapes, dtypes-ranges and hyperparameters.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.rd_assign import rd_assign, BLOCK
from compile.kernels.dequant import dequant
from compile.kernels.ref import rd_assign_ref, dequant_ref


def _mk_cost(k, slope, base=1.0):
    half = k // 2
    return ((np.abs(np.arange(k) - half) * slope) + base).astype(np.float32)


def _run_pair(w, fim, delta, lam, cost):
    out = np.asarray(rd_assign(jnp.asarray(w), jnp.asarray(fim),
                               jnp.asarray([delta], jnp.float32),
                               jnp.asarray([lam], jnp.float32),
                               jnp.asarray(cost)))
    ref = np.asarray(rd_assign_ref(jnp.asarray(w), jnp.asarray(fim),
                                   delta, lam, jnp.asarray(cost)))
    return out, ref


class TestRdAssignBasics:
    def test_zero_lambda_is_nearest_neighbor(self):
        rng = np.random.default_rng(0)
        w = rng.normal(0, 0.1, BLOCK).astype(np.float32)
        fim = np.ones(BLOCK, np.float32)
        delta = 0.02
        out, ref = _run_pair(w, fim, delta, 0.0, _mk_cost(65, 0.0))
        assert (out == ref).all()
        # lam=0 and flat costs -> pure nearest neighbour
        nn = np.clip(np.round(w / delta), -32, 32).astype(np.int32)
        assert (out == nn).all()

    def test_large_lambda_collapses_to_cheapest_symbol(self):
        rng = np.random.default_rng(1)
        w = rng.normal(0, 0.05, BLOCK).astype(np.float32)
        fim = np.ones(BLOCK, np.float32)
        cost = _mk_cost(33, 2.0)  # zero index cheapest
        out, _ = _run_pair(w, fim, 0.01, 1e9, cost)
        assert (out == 0).all()

    def test_fim_zero_ignores_distortion(self):
        w = np.full(BLOCK, 0.31, np.float32)
        fim = np.zeros(BLOCK, np.float32)
        cost = _mk_cost(33, 1.0)
        out, ref = _run_pair(w, fim, 0.01, 1.0, cost)
        assert (out == ref).all()
        assert (out == 0).all()  # cheapest = zero symbol

    def test_high_fim_pins_to_nearest(self):
        rng = np.random.default_rng(2)
        w = rng.normal(0, 0.1, BLOCK).astype(np.float32)
        fim = np.full(BLOCK, 1e9, np.float32)
        cost = _mk_cost(129, 3.0)
        delta = 0.01
        out, _ = _run_pair(w, fim, delta, 0.5, cost)
        nn = np.clip(np.round(w / delta), -64, 64).astype(np.int32)
        assert (out == nn).all()

    def test_multi_block(self):
        rng = np.random.default_rng(3)
        n = 4 * BLOCK
        w = rng.normal(0, 0.2, n).astype(np.float32)
        fim = rng.uniform(0.01, 10, n).astype(np.float32)
        out, ref = _run_pair(w, fim, 0.03, 0.02, _mk_cost(257, 1.2))
        assert (out == ref).all()

    def test_asymmetric_cost_table(self):
        rng = np.random.default_rng(4)
        w = rng.normal(0, 0.1, BLOCK).astype(np.float32)
        fim = np.ones(BLOCK, np.float32)
        k = 65
        cost = _mk_cost(k, 1.0)
        cost[: k // 2] += 0.7  # negatives dearer (sign-context asymmetry)
        out, ref = _run_pair(w, fim, 0.02, 0.05, cost)
        assert (out == ref).all()


@settings(max_examples=40, deadline=None)
@given(
    blocks=st.integers(1, 4),
    k=st.sampled_from([3, 9, 33, 129, 1025]),
    delta=st.floats(1e-4, 0.5, allow_nan=False, allow_infinity=False),
    lam=st.floats(0, 10.0, allow_nan=False, allow_infinity=False),
    seed=st.integers(0, 2 ** 31 - 1),
    scale=st.floats(1e-3, 2.0),
)
def test_rd_assign_matches_ref_hypothesis(blocks, k, delta, lam, seed, scale):
    rng = np.random.default_rng(seed)
    n = blocks * BLOCK
    w = rng.normal(0, scale, n).astype(np.float32)
    fim = rng.uniform(0, 5, n).astype(np.float32)
    cost = (rng.uniform(0.5, 20, k)).astype(np.float32)
    out, ref = _run_pair(w, fim, float(delta), float(lam), cost)
    assert (out == ref).all()


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(1, 3),
    delta=st.floats(1e-4, 1.0, allow_nan=False, allow_infinity=False),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_dequant_matches_ref_hypothesis(blocks, delta, seed):
    rng = np.random.default_rng(seed)
    n = blocks * BLOCK
    idx = rng.integers(-512, 513, n).astype(np.int32)
    out = np.asarray(dequant(jnp.asarray(idx),
                             jnp.asarray([delta], jnp.float32)))
    ref = np.asarray(dequant_ref(jnp.asarray(idx), np.float32(delta)))
    assert (out == ref).all()


def test_dequant_roundtrip_with_assignment():
    """dequant(rd_assign(w)) approximates w within delta/2 when lam=0."""
    rng = np.random.default_rng(7)
    w = rng.uniform(-0.3, 0.3, BLOCK).astype(np.float32)
    fim = np.ones(BLOCK, np.float32)
    delta = 0.01
    cost = _mk_cost(129, 0.0)
    idx = rd_assign(jnp.asarray(w), jnp.asarray(fim),
                    jnp.asarray([delta], jnp.float32),
                    jnp.asarray([0.0], jnp.float32), jnp.asarray(cost))
    q = np.asarray(dequant(idx, jnp.asarray([delta], jnp.float32)))
    # inside the grid range, reconstruction error <= delta/2 (+eps)
    inside = np.abs(w) <= 64 * delta
    assert np.abs(q - w)[inside].max() <= delta / 2 + 1e-6


def test_rd_assign_rejects_unaligned():
    with pytest.raises(AssertionError):
        rd_assign(jnp.zeros(BLOCK + 1), jnp.ones(BLOCK + 1),
                  jnp.asarray([0.1], jnp.float32),
                  jnp.asarray([0.0], jnp.float32), jnp.zeros(3))
